"""Decoder / encoder stacks with scan-over-layers + remat.

Layer parameters are STACKED on a leading [L, ...] axis and consumed by
``lax.scan`` -- the compiled HLO is one layer body regardless of depth,
which keeps multi-pod lowering fast and makes the remat policy a single
``jax.checkpoint`` on the scan body.

Families:
  dense / moe         attention + (SwiGLU | MoE) blocks
  ssm                 Mamba-1 blocks (falcon-mamba)
  hybrid              Mamba-2 blocks + ONE shared attention block applied
                      every ``shared_attn_every`` layers (zamba2)
  audio (enc-dec)     bidirectional encoder + causal decoder with
                      cross-attention (whisper)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import autotune
from repro.models.attention import attention, windowed_variant
from repro.models.layers import apply_rope, gelu_mlp, layer_norm, rms_norm, rotary_embedding, swiglu
from repro.models.moe import moe_ffn
from repro.models.ssm import mamba1_block, mamba2_block

Params = dict[str, Any]


def _tuned_blocks(cfg: ModelConfig, kernel: str, key: dict,
                  default: tuple[int, int]) -> tuple[int, int]:
    """Trace-time autotune-cache lookup (no-op unless
    cfg.kernel_autotune; env override always wins)."""
    return autotune.resolve(kernel, key, default,
                            enabled=cfg.kernel_autotune,
                            cache_path=cfg.autotune_cache)


def _ssm_kwargs(cfg: ModelConfig, T: int) -> dict:
    """Backend/block kwargs for the mamba blocks.  The scan backend
    keeps its historical chunking defaults; the pallas backend takes
    block_d/chunk from the config (or the autotune cache)."""
    if cfg.ssm_backend != "pallas":
        return {}
    bd, ct = _tuned_blocks(
        cfg, "scan",
        dict(T=T, di=cfg.d_inner, N=cfg.ssm_state, dtype=cfg.dtype),
        (cfg.ssm_block_d, cfg.ssm_chunk))
    return dict(backend="pallas", block_d=bd, chunk=ct)


def _norm(cfg: ModelConfig, x, scale):
    if cfg.nonparametric_norm:
        return layer_norm(x, None, None)
    if cfg.family == "audio":
        return layer_norm(x, scale, None)
    return rms_norm(x, scale)


def _attend(cfg: ModelConfig, p: Params, x, seg, pos, sin, cos, *,
            causal=True, kv=None, kv_seg=None, kv_pos=None, impl=None):
    """Shared attention core.  kv!=None -> cross attention (no rope, no
    sliding window; segment pairing keeps each example attending to its
    own encoder output)."""
    B, T, D = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = jnp.einsum("btd,dhe->bthe", x, p["wq"].reshape(D, H, hd))
    src = x if kv is None else kv
    k = jnp.einsum("btd,dhe->bthe", src, p["wk"].reshape(src.shape[-1], Hkv, hd))
    v = jnp.einsum("btd,dhe->bthe", src, p["wv"].reshape(src.shape[-1], Hkv, hd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if kv is None:  # self attention: rope on both
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
        kv_seg, kv_pos = seg, pos
    backend = impl or cfg.attention_backend
    if cfg.segment_window and kv is None and backend != "reference":
        backend = windowed_variant(backend)
    bq, bk = cfg.block_q, cfg.block_kv
    if backend.startswith("flash"):
        bq, bk = _tuned_blocks(
            cfg, "flash",
            dict(Tq=T, Tkv=k.shape[1], D=hd, H=H, dtype=cfg.dtype),
            (bq, bk))
    out = attention(
        q, k, v,
        q_seg=seg, kv_seg=kv_seg, q_pos=pos, kv_pos=kv_pos,
        causal=causal, window=cfg.sliding_window if kv is None else None,
        backend=backend,
        block_q=bq, block_kv=bk,
        chunk_w=cfg.segment_window,
    )
    return jnp.einsum("bthe,hed->btd", out, p["wo"].reshape(H, hd, D))


def _ffn(cfg: ModelConfig, p: Params, x, valid):
    if cfg.family == "moe":
        B, T, d = x.shape
        bm, bn = _tuned_blocks(
            cfg, "grouped",
            dict(M=B * T * cfg.experts_per_token, K=d, N=cfg.d_ff,
                 E=cfg.n_experts, dtype=cfg.dtype),
            (cfg.moe_block_m, cfg.moe_block_n))
        return moe_ffn(
            x, p["router"], p["w_gate"], p["w_up"], p["w_down"],
            top_k=cfg.experts_per_token, capacity_factor=cfg.capacity_factor,
            valid=valid, shard_buffers=cfg.moe_shard_buffers,
            backend=cfg.moe_backend, block_m=bm, block_n=bn,
        )
    if cfg.family == "audio":
        return gelu_mlp(x, p["w_in"], p["w_out"]), jnp.float32(0.0)
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), jnp.float32(0.0)


def _attn_mlp_layer(cfg: ModelConfig, p: Params, x, seg, pos, sin, cos, *, causal=True):
    h = _norm(cfg, x, p.get("attn_norm"))
    x = x + _attend(cfg, p, h, seg, pos, sin, cos, causal=causal)
    h = _norm(cfg, x, p.get("mlp_norm"))
    ff, aux = _ffn(cfg, p, h, seg > 0)
    return x + ff, aux


# ----------------------------------------------------------------------
# Forward stacks (training / prefill).
# ----------------------------------------------------------------------
def decoder_stack(cfg: ModelConfig, params: Params, x, seg, pos):
    """x [B,T,D] -> ([B,T,D], aux).  ``aux`` is the scalar aux loss,
    except for the moe family where it is a dict (``lb_loss`` scalar
    summed over layers plus ``expert_load`` [E] / ``dropped_frac``
    metrics averaged over layers).  params["layers"] leaves are stacked
    [L, ...]."""
    sin, cos = rotary_embedding(pos, cfg.head_dim_, cfg.rope_theta)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            y, aux = _attn_mlp_layer(cfg, lp, carry, seg, pos, sin, cos)
            return y, aux

        body = jax.checkpoint(body) if cfg.remat else body
        x, auxs = jax.lax.scan(body, x, params["layers"],
                               unroll=min(cfg.scan_unroll, cfg.n_layers))
        if cfg.family == "moe":
            return x, {
                "lb_loss": auxs["lb_loss"].sum(),
                "expert_load": auxs["expert_load"].mean(axis=0),
                "dropped_frac": auxs["dropped_frac"].mean(),
            }
        return x, auxs.sum()

    if cfg.family == "ssm":
        ssm_kw = _ssm_kwargs(cfg, x.shape[1])

        def body(carry, lp):
            h = _norm(cfg, carry, lp.get("norm"))
            y = mamba1_block(lp, h, seg, ssm_state=cfg.ssm_state, **ssm_kw)
            return carry + y, jnp.float32(0.0)

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["layers"],
                            unroll=min(cfg.scan_unroll, cfg.n_layers))
        return x, jnp.float32(0.0)

    if cfg.family == "hybrid":
        return _hybrid_stack(cfg, params, x, seg, pos, sin, cos)

    raise ValueError(f"decoder_stack does not handle family {cfg.family}")


def _hybrid_stack(cfg: ModelConfig, params: Params, x, seg, pos, sin, cos):
    """zamba2: groups of mamba2 layers, shared attention block between
    groups (one weight set reused -- the Zamba trick)."""
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    shared = params["shared_attn"]

    # params["layers"] leaves are [L, ...]; reshape to [n_groups, every, ...].
    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"]
    )

    ssm_kw = _ssm_kwargs(cfg, x.shape[1])

    def mamba_body(carry, lp):
        h = _norm(cfg, carry, lp.get("norm"))
        y = mamba2_block(lp, h, seg, ssm_state=cfg.ssm_state,
                         headdim=cfg.ssm_headdim, **ssm_kw)
        return carry + y, None

    mamba_body_ck = jax.checkpoint(mamba_body) if cfg.remat else mamba_body

    # Roofline mode: unroll the inner mamba scan so every layer's FLOPs
    # are visible to cost_analysis (outer scan handled by extrapolation).
    inner_unroll = every if cfg.attention_backend == "chunked_unrolled" else 1

    def group_body(carry, gp):
        y, _ = jax.lax.scan(mamba_body_ck, carry, gp, unroll=inner_unroll)
        y2, _ = _attn_mlp_layer(cfg, shared, y, seg, pos, sin, cos)
        return y2, None

    group_body = jax.checkpoint(group_body) if cfg.remat else group_body
    x, _ = jax.lax.scan(group_body, x, grouped,
                        unroll=min(cfg.scan_unroll, n_groups))
    return x, jnp.float32(0.0)


def encoder_stack(cfg: ModelConfig, params: Params, x, seg, pos):
    """Bidirectional encoder (whisper); LayerNorm + GELU, no rope mixing
    across segments."""
    sin, cos = rotary_embedding(pos, cfg.head_dim_, cfg.rope_theta)

    def body(carry, lp):
        y, _ = _attn_mlp_layer(cfg, lp, carry, seg, pos, sin, cos, causal=False)
        return y, None

    body = jax.checkpoint(body) if cfg.remat else body
    L = jax.tree_util.tree_leaves(params["enc_layers"])[0].shape[0]
    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=min(cfg.scan_unroll, L))
    return x


def cross_decoder_stack(cfg: ModelConfig, params: Params, x, seg, pos,
                        enc_out, enc_seg, enc_pos):
    """Whisper decoder: self-attn (causal) + cross-attn + GELU MLP."""
    sin, cos = rotary_embedding(pos, cfg.head_dim_, cfg.rope_theta)

    def body(carry, lp):
        h = _norm(cfg, carry, lp.get("attn_norm"))
        carry = carry + _attend(cfg, lp, h, seg, pos, sin, cos, causal=True)
        h = _norm(cfg, carry, lp.get("cross_norm"))
        carry = carry + _attend(
            cfg, _cross_params(lp), h, seg, pos, sin, cos,
            causal=False, kv=enc_out, kv_seg=enc_seg, kv_pos=enc_pos,
        )
        h = _norm(cfg, carry, lp.get("mlp_norm"))
        ff, _ = _ffn(cfg, lp, h, seg > 0)
        return carry + ff, None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=min(cfg.scan_unroll, cfg.n_layers))
    return x


def _cross_params(lp: Params) -> Params:
    return {
        "wq": lp["xwq"], "wk": lp["xwk"], "wv": lp["xwv"], "wo": lp["xwo"],
        "q_norm": lp.get("q_norm"), "k_norm": lp.get("k_norm"),
    }
