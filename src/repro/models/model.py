"""Model assembly: parameter init, train/prefill forward, loss.

The forward contract consumes POST-BALANCED batches: per-DP-shard packed
token streams plus (for multimodal archs) per-encoder packed embedding
streams and the orchestrator's composed rearrangement plan (paper S6).

Batch keys (all leading dim S = total DP shards):
  tokens      [S, cap_T]  int32   packed text tokens
  labels      [S, cap_T]  int32   next-token targets; -1 = no loss
  text_dst    [S, cap_T]  int32   slot in the interleaved LLM stream
                                  (cap_L = dropped/padding)
  llm_seg     [S, cap_L]  int32   segment ids of the interleaved stream
  llm_pos     [S, cap_L]  int32   positions (restart per example)
  per encoder <e> (vlm / mllm families):
    enc_<e>_embeds [S, cap_E, embed_dim]   stub frontend output
    enc_<e>_seg/pos [S, cap_E]
    enc_<e>_plan_*  communicator arrays (composed Pi_M o Pi_E^-1)
    enc_<e>_dst  [S, cap_Eo] int32         slot in LLM stream after exchange
  audio (enc-dec) family:
    enc_embeds/enc_seg/enc_pos             encoder stream (stays separate,
                                           exchanged to the decoder's shard)
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import EncoderConfig, ModelConfig
from repro.models.layers import init_dense, layer_norm, rms_norm
from repro.models.transformer import (
    cross_decoder_stack,
    decoder_stack,
    encoder_stack,
)

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------
# Init.
# ----------------------------------------------------------------------
def _init_attn(key, cfg: ModelConfig, L, D, dt) -> Params:
    hd, H, Hkv = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_dense(ks[0], (L, D, H * hd), dt),
        "wk": init_dense(ks[1], (L, D, Hkv * hd), dt),
        "wv": init_dense(ks[2], (L, D, Hkv * hd), dt),
        "wo": init_dense(ks[3], (L, H * hd, D), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((L, hd), dt)
        p["k_norm"] = jnp.ones((L, hd), dt)
    return p


def _init_dense_mlp(key, L, D, F, dt) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(ks[0], (L, D, F), dt),
        "w_up": init_dense(ks[1], (L, D, F), dt),
        "w_down": init_dense(ks[2], (L, F, D), dt),
    }


def _init_moe_mlp(key, cfg: ModelConfig, L, D, F, dt) -> Params:
    ks = jax.random.split(key, 4)
    E = cfg.n_experts
    return {
        "router": init_dense(ks[0], (L, D, E), jnp.float32),
        "w_gate": init_dense(ks[1], (L, E, D, F), dt),
        "w_up": init_dense(ks[2], (L, E, D, F), dt),
        "w_down": init_dense(ks[3], (L, E, F, D), dt),
    }


def _init_mamba1(key, cfg: ModelConfig, L, dt) -> Params:
    D, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dt_rank = max(1, D // 16)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "norm": jnp.ones((L, D), dt),
        "in_proj": init_dense(ks[0], (L, D, 2 * di), dt),
        "conv_w": init_dense(ks[1], (L, K, di), dt, scale=0.5),
        "x_proj": init_dense(ks[2], (L, di, dt_rank + 2 * N), dt),
        "dt_proj": init_dense(ks[3], (L, dt_rank, di), dt),
        "dt_bias": jnp.zeros((L, di), dt),
        "A_log": jnp.tile(jnp.log(A)[None], (L, 1, 1)),
        "D": jnp.ones((L, di), jnp.float32),
        "out_proj": init_dense(ks[4], (L, di, D), dt),
    }


def _init_mamba2(key, cfg: ModelConfig, L, dt) -> Params:
    D, di, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    H = di // cfg.ssm_headdim
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((L, D), dt),
        "in_proj": init_dense(ks[0], (L, D, 2 * di + 2 * N + H), dt),
        "conv_w": init_dense(ks[1], (L, K, di), dt, scale=0.5),
        "dt_bias": jnp.zeros((L, H), dt),
        "A_log": jnp.zeros((L, H), jnp.float32),
        "D": jnp.ones((L, H), jnp.float32),
        "out_proj": init_dense(ks[2], (L, di, D), dt),
    }


def _init_block_norms(cfg: ModelConfig, L, D, dt) -> Params:
    if cfg.nonparametric_norm:
        return {}
    return {"attn_norm": jnp.ones((L, D), dt), "mlp_norm": jnp.ones((L, D), dt)}


def _init_encoder(key, e: EncoderConfig, d_llm: int, dt) -> Params:
    """Modality encoder transformer (paper submodule) + MLP connector."""
    ks = jax.random.split(key, 8)
    L, D, F = e.n_layers, e.d_model, e.d_ff
    p: Params = {
        "input_proj": init_dense(ks[0], (e.embed_dim, D), dt),
        # Connector (paper: MLPs universally).
        "conn_in": init_dense(ks[1], (D * e.downsample, d_llm), dt),
        "conn_out": init_dense(ks[2], (d_llm, d_llm), dt),
    }
    if L > 0:
        p["layers"] = {
            "attn_norm": jnp.ones((L, D), dt),
            "mlp_norm": jnp.ones((L, D), dt),
            "wq": init_dense(ks[3], (L, D, D), dt),
            "wk": init_dense(ks[4], (L, D, D), dt),
            "wv": init_dense(ks[5], (L, D, D), dt),
            "wo": init_dense(ks[6], (L, D, D), dt),
            # ViT/whisper-style GELU MLP (matches the "audio" forward path).
            "w_in": init_dense(ks[7], (L, D, F), dt),
            "w_out": init_dense(jax.random.fold_in(ks[7], 1), (L, F, D), dt),
        }
        p["final_norm"] = jnp.ones((D,), dt)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dt = _dtype(cfg)
    D, F, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab_size
    keys = jax.random.split(key, 12)
    params: Params = {"embed": init_dense(keys[0], (V, D), dt, scale=1.0)}

    if cfg.family in ("dense", "moe", "vlm"):
        layers = _init_block_norms(cfg, L, D, dt)
        layers.update(_init_attn(keys[1], cfg, L, D, dt))
        if cfg.family == "moe":
            layers.update(_init_moe_mlp(keys[2], cfg, L, D, F, dt))
        else:
            layers.update(_init_dense_mlp(keys[2], L, D, F, dt))
        params["layers"] = layers
    elif cfg.family == "ssm":
        params["layers"] = _init_mamba1(keys[1], cfg, L, dt)
    elif cfg.family == "hybrid":
        params["layers"] = _init_mamba2(keys[1], cfg, L, dt)
        shared = {"attn_norm": jnp.ones((D,), dt), "mlp_norm": jnp.ones((D,), dt)}
        sa = _init_attn(keys[2], cfg, 1, D, dt)
        shared.update({k: v[0] for k, v in sa.items()})
        shared.update({k: v[0] for k, v in _init_dense_mlp(keys[3], 1, D, F, dt).items()})
        params["shared_attn"] = shared
    elif cfg.family == "audio":
        eL = cfg.encoder_layers
        enc = {"attn_norm": jnp.ones((eL, D), dt), "mlp_norm": jnp.ones((eL, D), dt)}
        enc.update(_init_attn(keys[1], cfg, eL, D, dt))
        enc.update({
            "w_in": init_dense(keys[2], (eL, D, F), dt),
            "w_out": init_dense(keys[3], (eL, F, D), dt),
        })
        params["enc_layers"] = enc
        dec = {
            "attn_norm": jnp.ones((L, D), dt),
            "cross_norm": jnp.ones((L, D), dt),
            "mlp_norm": jnp.ones((L, D), dt),
        }
        dec.update(_init_attn(keys[4], cfg, L, D, dt))
        xa = _init_attn(keys[5], cfg, L, D, dt)
        dec.update({"x" + k: v for k, v in xa.items() if k.startswith("w")})
        dec.update({
            "w_in": init_dense(keys[6], (L, D, F), dt),
            "w_out": init_dense(keys[7], (L, F, D), dt),
        })
        params["layers"] = dec
    else:
        raise ValueError(cfg.family)

    if not cfg.nonparametric_norm:
        params["final_norm"] = jnp.ones((D,), dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(keys[8], (D, V), dt)

    for i, e in enumerate(cfg.encoders):
        if cfg.family == "audio":
            # Enc-dec: the encoder stack lives in the model itself
            # (enc_layers at d_model); only the frontend-stub projection
            # is per-encoder.
            params[f"encoder_{e.name}"] = {
                "input_proj": init_dense(keys[9 + i], (e.embed_dim, D), dt)
            }
        else:
            params[f"encoder_{e.name}"] = _init_encoder(keys[9 + i], e, D, dt)
    return params


# ----------------------------------------------------------------------
# Loss (chunked: never materializes [T, V] for the full stream).
# ----------------------------------------------------------------------
def chunked_xent(x, lm_head, labels, *, chunk: int = 2048, unroll: int = 1):
    """x [B,T,D], labels [B,T] (-1 = ignore) -> (sum_loss, n_valid).

    The chunk body is checkpointed: backward recomputes each chunk's
    logits instead of keeping [T, V] alive (HBM would not fit)."""
    B, T, D = x.shape
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        xs, ls = inp
        logits = jnp.einsum("bcd,dv->bcv", xs.astype(jnp.float32),
                            lm_head.astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.clip(ls, 0)[..., None], axis=-1
        )[..., 0]
        valid = ls >= 0
        loss = jnp.where(valid, logz - gold, 0.0)
        s, n = carry
        return (s + loss.sum(), n + valid.sum()), None

    (s, n), _ = jax.lax.scan(body, (jnp.float32(0), jnp.int32(0)), (xc, lc),
                             unroll=min(unroll, n_chunks))
    return s, n


# ----------------------------------------------------------------------
# Forward (training / prefill).
# ----------------------------------------------------------------------
def run_encoder(cfg_e: EncoderConfig, p: Params, embeds, seg, pos, *,
                base_cfg: ModelConfig):
    """Stub-frontend embeddings -> connector tokens in LLM space.

    Returns [S, cap_E // downsample, d_llm]."""
    x = jnp.einsum("ste,ed->std", embeds.astype(_dtype(base_cfg)), p["input_proj"])
    if cfg_e.n_layers > 0:
        enc_cfg = _encoder_model_cfg(cfg_e, base_cfg)
        x = encoder_stack(enc_cfg, {"enc_layers": p["layers"]}, x, seg, pos)
        x = rms_norm(x, p["final_norm"])
    ds = cfg_e.downsample
    S, T, D = x.shape
    x = x.reshape(S, T // ds, D * ds)
    x = jnp.einsum("std,de->ste", x, p["conn_in"])
    return jnp.einsum("ste,ef->stf", jax.nn.gelu(x), p["conn_out"])


def _encoder_model_cfg(e: EncoderConfig, base: ModelConfig) -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        base,
        family="audio",  # LayerNorm + GELU path
        n_layers=e.n_layers,
        scan_unroll=e.scan_unroll,
        d_model=e.d_model,
        n_heads=e.n_heads,
        n_kv_heads=e.n_heads,
        head_dim=None,
        d_ff=e.d_ff,
        qk_norm=False,
        sliding_window=None,
        nonparametric_norm=False,
    )


def forward(cfg: ModelConfig, params: Params, batch: dict[str, jnp.ndarray],
            *, exchange: Callable | None = None):
    """Returns (sum_loss, n_tokens, aux_loss).

    ``exchange(name, tokens)``: the orchestrator's communicator closure
    that moves encoder-output tokens to their destination shards
    (composed rearrangement); identity when running single-host tests.
    """
    dt = _dtype(cfg)
    tokens = batch["tokens"]
    S, cap_T = tokens.shape

    if cfg.family == "audio":
        return _forward_encdec(cfg, params, batch, exchange)

    if cfg.encoders:
        cap_L = batch["llm_seg"].shape[1]
        x = jnp.zeros((S, cap_L, cfg.d_model), dt)
        text_emb = jnp.take(params["embed"], tokens, axis=0)
        # Scatter text tokens into their interleaved slots (index cap_L drops).
        x = _scatter_tokens(x, batch["text_dst"], text_emb)
        for e in cfg.encoders:
            p_e = params[f"encoder_{e.name}"]
            enc_tok = run_encoder(
                e, p_e, batch[f"enc_{e.name}_embeds"],
                batch[f"enc_{e.name}_seg"], batch[f"enc_{e.name}_pos"],
                base_cfg=cfg,
            )
            if exchange is not None:
                enc_tok = exchange(e.name, enc_tok)
            x = _scatter_tokens(x, batch[f"enc_{e.name}_dst"], enc_tok)
        seg, pos = batch["llm_seg"], batch["llm_pos"]
        labels = batch["llm_labels"]
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
        seg, pos = batch["seg"], batch["pos"]
        labels = batch["labels"]

    x, aux = decoder_stack(cfg, params, x, seg, pos)
    x = _final_norm(cfg, params, x)
    lm_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    loss_sum, n = chunked_xent(x, lm_head, labels, unroll=_xent_unroll(cfg))
    return loss_sum, n, aux


def _forward_encdec(cfg, params, batch, exchange):
    dt = _dtype(cfg)
    e = cfg.encoders[0]
    p_e = params[f"encoder_{e.name}"]
    # Frontend-stub embeddings -> encoder input space.
    enc_in = jnp.einsum("ste,ed->std", batch[f"enc_{e.name}_embeds"].astype(dt),
                        p_e["input_proj"])
    enc_seg, enc_pos = batch[f"enc_{e.name}_seg"], batch[f"enc_{e.name}_pos"]
    enc_out = encoder_stack(cfg, {"enc_layers": params["enc_layers"]},
                            enc_in, enc_seg, enc_pos)
    if exchange is not None:
        enc_out = exchange(e.name, enc_out)
        enc_seg = batch[f"enc_{e.name}_seg_out"]
        enc_pos = batch[f"enc_{e.name}_pos_out"]
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = cross_decoder_stack(cfg, params, x, batch["seg"], batch["pos"],
                            enc_out, enc_seg, enc_pos)
    x = _final_norm(cfg, params, x)
    lm_head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    loss_sum, n = chunked_xent(x, lm_head, batch["labels"], unroll=_xent_unroll(cfg))
    return loss_sum, n, jnp.float32(0.0)


def _xent_unroll(cfg):
    # Roofline mode: unrolled scans so cost_analysis counts every chunk.
    return 10**9 if cfg.attention_backend == "chunked_unrolled" else 1


def _final_norm(cfg, params, x):
    if cfg.nonparametric_norm:
        return layer_norm(x, None, None)
    if cfg.family == "audio":
        return layer_norm(x, params["final_norm"], None)
    return rms_norm(x, params["final_norm"])


def _scatter_tokens(x, dst, values):
    """x [S, cap_L, D]; dst [S, T] slots (cap_L = drop); values [S, T, D]."""
    S, cap_L, D = x.shape

    def one(xs, ds, vs):
        padded = jnp.concatenate([xs, jnp.zeros((1, D), xs.dtype)], axis=0)
        padded = padded.at[ds].set(vs.astype(xs.dtype), mode="drop")
        return padded[:cap_L]

    return jax.vmap(one)(x, dst, values)
