"""Mixture-of-Experts FFN (grok-1: 8e top-2; granite: 40e top-8).

Sort-based capacity dispatch (TPU-friendly, static shapes):
  1. router logits -> top-k (expert id, weight) per token
  2. flatten (token, k) assignments, sort by expert id
  3. slot within expert = rank inside its expert's contiguous run
  4. scatter tokens into a [E, C, d] buffer (drop beyond capacity C)
  5. batched expert matmuls [E,C,d] x [E,d,f]
  6. gather back and combine with router weights

Expert parallelism: the [E,C,*] buffers and expert weights carry
sharding constraints over the ``model`` mesh axis (weights: d_ff dim;
buffers: capacity dim), so the big matmuls are tensor-parallel within
each expert -- this avoids requiring n_experts % mesh_model == 0
(grok has 8 experts on a 16-wide model axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_ffn", "router_load_balance_loss"]


def moe_ffn(
    x: jnp.ndarray,
    router_w: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    valid: jnp.ndarray | None = None,
    shard_buffers: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, d]; router_w: [d, E]; w_*: [E, d, f] / [E, f, d].

    ``valid``: [B, T] bool -- padding tokens get zero routing weight so
    they never steal capacity (post-balancing keeps padding minimal, but
    the packed stream tail may be padded to the static capacity).

    Returns (output [B,T,d], aux metrics dict packed as an array tuple).
    """
    B, T, d = x.shape
    E = router_w.shape[-1]
    n = B * T
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router_w.astype(jnp.float32))
    if valid is not None:
        logits = jnp.where(valid.reshape(n, 1), logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_ids = jax.lax.top_k(probs, top_k)  # [n, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    if valid is not None:
        gate_vals = gate_vals * valid.reshape(n, 1)

    # Flatten assignments and sort by expert.
    flat_e = gate_ids.reshape(-1)  # [n*k]
    flat_tok = jnp.repeat(jnp.arange(n), top_k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]

    # Rank within expert run: position - start_of_expert.
    counts = jnp.zeros(E, jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * top_k) - starts[sorted_e]

    capacity = int(max(1, round(n * top_k / E * capacity_factor)))
    keep = rank < capacity
    slot = jnp.where(keep, sorted_e * capacity + rank, E * capacity)  # overflow -> dropped row

    buf = jnp.zeros((E * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(xf[sorted_tok], mode="drop")
    buf = buf[:-1].reshape(E, capacity, d)
    if shard_buffers:
        # S-Perf knob: pin the dispatch buffer's capacity dim to the
        # model axis so expert matmuls parallelize over C instead of
        # round-tripping through resharding collectives.
        from jax.sharding import PartitionSpec as _P

        buf = jax.lax.with_sharding_constraint(buf, _P(None, "model", None))

    # Expert matmuls (tensor-parallel over f via weight sharding).
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E * capacity, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)], axis=0)

    # Gather back to (token, k) order and combine.
    expert_out = out_buf[slot]  # [n*k, d] (dropped -> zeros row)
    inv = jnp.argsort(order, stable=True)
    expert_out = expert_out[inv].reshape(n, top_k, d)
    combined = jnp.einsum("nkd,nk->nd", expert_out.astype(jnp.float32),
                          gate_vals.astype(jnp.float32))

    aux = router_load_balance_loss(probs, gate_ids, E, valid.reshape(n) if valid is not None else None)
    return combined.reshape(B, T, d).astype(x.dtype), aux


def router_load_balance_loss(
    probs: jnp.ndarray, gate_ids: jnp.ndarray, n_experts: int,
    valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e fraction_tokens_e * mean_prob_e."""
    n = probs.shape[0]
    top1 = gate_ids[:, 0]
    onehot = jax.nn.one_hot(top1, n_experts, dtype=jnp.float32)
    if valid is not None:
        onehot = onehot * valid[:, None]
        denom = jnp.clip(valid.sum(), 1.0)
    else:
        denom = float(n)
    frac = onehot.sum(0) / denom
    mean_p = probs.mean(0)
    return n_experts * jnp.sum(frac * mean_p)
