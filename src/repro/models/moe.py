"""Mixture-of-Experts FFN (grok-1: 8e top-2; granite: 40e top-8).

Two dispatch backends behind ``moe_ffn(backend=...)``, sharing one
routing prologue (top-k over router softmax, invalid/padding
assignments remapped to a sentinel expert so they never steal capacity
or rows):

  "dense"    legacy sort + scatter into a [E, capacity, d] buffer:
             static shapes, but pays E*capacity rows of matmul and
             silently drops assignments past capacity (the dropped
             fraction is now reported as an aux metric).

  "grouped"  drop-free sorted dispatch: tokens sorted by expert form
             contiguous variable-length groups, and the three expert
             matmuls run through the Pallas grouped-GEMM kernel
             (``kernels/grouped_gemm.py``) with scalar-prefetch group
             offsets and tile-skip over empty experts.  Work scales
             with the routed rows (aligned up to the tile), not with
             E * max-capacity, no matter how imbalanced the routing.

Both return aux metrics: the Switch-style load-balance loss over ALL
top-k slots, the realized per-expert load fractions, and the dropped
fraction (identically 0.0 for "grouped").

Token-to-expert routing is the paper's imbalanced-assignment problem
one level down: ``expert_shard_plan`` reuses the chunked-exact LPT
engine from ``core/balancing_vec.py`` to bin experts onto expert-
parallel shards from the *measured* loads the aux metrics report, and
to derive the capacity a drop-free dense dispatch would need.

Expert parallelism (dense path): the [E,C,*] buffers and expert
weights carry sharding constraints over the ``model`` mesh axis
(weights: d_ff dim; buffers: capacity dim), so the big matmuls are
tensor-parallel within each expert -- this avoids requiring
n_experts % mesh_model == 0 (grok has 8 experts on a 16-wide model
axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["moe_ffn", "router_load_balance_loss", "expert_shard_plan"]


def moe_ffn(
    x: jnp.ndarray,
    router_w: jnp.ndarray,
    w_gate: jnp.ndarray,
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    valid: jnp.ndarray | None = None,
    shard_buffers: bool = False,
    backend: str = "dense",
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """x: [B, T, d]; router_w: [d, E]; w_*: [E, d, f] / [E, f, d].

    ``valid``: [B, T] bool -- padding tokens get zero routing weight and
    are remapped to a sentinel expert, so they never steal capacity
    (post-balancing keeps padding minimal, but the packed stream tail
    may be padded to the static shape).

    Returns ``(output [B,T,d], aux)`` where ``aux`` is a dict of
    metrics:

      "lb_loss"       Switch-style load-balance loss (scalar; counts
                      all top-k slots)
      "expert_load"   [E] realized fraction of routed assignments
      "dropped_frac"  fraction of valid assignments dropped by the
                      capacity buffer (0.0 on the drop-free "grouped"
                      backend)
    """
    if backend not in ("dense", "grouped"):
        raise ValueError(f"unknown moe backend {backend!r}")
    B, T, d = x.shape
    E = router_w.shape[-1]
    n = B * T
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router_w.astype(jnp.float32))
    if valid is not None:
        logits = jnp.where(valid.reshape(n, 1), logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_ids = jax.lax.top_k(probs, top_k)  # [n, k]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    if valid is not None:
        gate_vals = gate_vals * valid.reshape(n, 1)

    # Flatten assignments; invalid tokens route to sentinel expert E
    # (sorts past every real expert -> zero rows, zero capacity use).
    flat_e = gate_ids.reshape(-1)  # [n*k]
    if valid is not None:
        flat_e = jnp.where(jnp.repeat(valid.reshape(n), top_k), flat_e, E)
    flat_tok = jnp.repeat(jnp.arange(n), top_k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = flat_tok[order]

    counts = jnp.zeros(E + 1, jnp.int32).at[flat_e].add(1)
    n_routed = jnp.maximum(counts[:E].sum(), 1)
    expert_load = counts[:E].astype(jnp.float32) / n_routed.astype(jnp.float32)

    if backend == "grouped":
        expert_out, dropped = _grouped_dispatch(
            xf, w_gate, w_up, w_down, sorted_tok, counts, n, top_k,
            block_m=block_m, block_n=block_n, interpret=interpret)
    else:
        expert_out, dropped = _dense_dispatch(
            xf, w_gate, w_up, w_down, sorted_e, sorted_tok, order, counts,
            n, top_k, E, capacity_factor, shard_buffers)

    inv = jnp.argsort(order, stable=True)
    expert_out = expert_out[inv].reshape(n, top_k, d)
    combined = jnp.einsum("nkd,nk->nd", expert_out.astype(jnp.float32),
                          gate_vals.astype(jnp.float32))

    aux = {
        "lb_loss": router_load_balance_loss(
            probs, gate_ids, E, valid.reshape(n) if valid is not None else None,
            top_k=top_k),
        "expert_load": expert_load,
        "dropped_frac": dropped.astype(jnp.float32) / n_routed.astype(jnp.float32),
    }
    return combined.reshape(B, T, d).astype(x.dtype), aux


def _dense_dispatch(xf, w_gate, w_up, w_down, sorted_e, sorted_tok, order,
                    counts, n, top_k, E, capacity_factor, shard_buffers):
    """Legacy capacity-buffer path.  Returns outputs in SORTED
    assignment order [n*k, d] plus the dropped-assignment count."""
    d = xf.shape[1]
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * top_k) - starts[sorted_e]

    capacity = int(max(1, round(n * top_k / E * capacity_factor)))
    keep = (rank < capacity) & (sorted_e < E)
    dropped = counts[:E].sum() - keep.sum()
    slot = jnp.where(keep, sorted_e * capacity + rank, E * capacity)

    buf = jnp.zeros((E * capacity + 1, d), xf.dtype)
    buf = buf.at[slot].set(xf[sorted_tok], mode="drop")
    buf = buf[:-1].reshape(E, capacity, d)
    if shard_buffers:
        # S-Perf knob: pin the dispatch buffer's capacity dim to the
        # model axis so expert matmuls parallelize over C instead of
        # round-tripping through resharding collectives.
        from jax.sharding import PartitionSpec as _P

        buf = jax.lax.with_sharding_constraint(buf, _P(None, "model", None))

    # Expert matmuls (tensor-parallel over f via weight sharding).
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E * capacity, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, d), out_buf.dtype)], axis=0)
    return out_buf[slot], dropped  # dropped slot -> zeros row


def _grouped_dispatch(xf, w_gate, w_up, w_down, sorted_tok, counts, n,
                      top_k, *, block_m, block_n, interpret):
    """Drop-free grouped-GEMM path.  Returns outputs in SORTED
    assignment order [n*k, d]; never drops (dropped count = 0)."""
    from repro.kernels.ops import grouped_matmul_op

    d = xf.shape[1]
    offsets = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts[:-1]).astype(jnp.int32)])
    xs = xf[sorted_tok]  # [n*k, d] sorted by expert; sentinel rows last
    M = n * top_k
    bm = min(block_m, M)
    pad = (-M) % bm
    if pad:
        xs = jnp.concatenate([xs, jnp.zeros((pad, d), xs.dtype)])

    g = grouped_matmul_op(xs, w_gate, offsets, block_m=bm,
                          block_n=_divisor_block(w_gate.shape[-1], block_n),
                          interpret=interpret)
    u = grouped_matmul_op(xs, w_up, offsets, block_m=bm,
                          block_n=_divisor_block(w_up.shape[-1], block_n),
                          interpret=interpret)
    h = jax.nn.silu(g) * u
    out = grouped_matmul_op(h, w_down, offsets, block_m=bm,
                            block_n=_divisor_block(d, block_n),
                            interpret=interpret)
    if pad:
        out = out[:M]
    return out, jnp.int32(0)


def _divisor_block(size: int, target: int) -> int:
    """Largest block <= target that divides size (trace-time helper)."""
    for b in range(min(target, size), 0, -1):
        if size % b == 0:
            return b
    return 1


def router_load_balance_loss(
    probs: jnp.ndarray, gate_ids: jnp.ndarray, n_experts: int,
    valid: jnp.ndarray | None = None, *, top_k: int | None = None,
) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e fraction_slots_e * mean_prob_e.

    Counts ALL top-k assignment slots (normalized by k) -- a top-8
    router whose 2nd..8th choices pile onto one expert is imbalanced
    even when the top-1 choices are uniform.  Balanced-uniform routing
    (uniform probs, uniform slot usage) gives exactly 1.0 for any k.
    """
    n, k = gate_ids.shape
    if top_k is not None and top_k != k:
        raise ValueError(f"top_k={top_k} != gate_ids k={k}")
    onehot = jax.nn.one_hot(gate_ids, n_experts, dtype=jnp.float32).sum(1) / k
    if valid is not None:
        vf = valid.astype(jnp.float32)
        onehot = onehot * vf[:, None]
        denom = jnp.clip(vf.sum(), 1.0)
        mean_p = (probs * vf[:, None]).sum(0) / denom
    else:
        denom = float(n)
        mean_p = probs.mean(0)
    frac = onehot.sum(0) / denom
    return n_experts * jnp.sum(frac * mean_p)


def expert_shard_plan(
    expert_load: np.ndarray, n_shards: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side planner: bin experts onto ``n_shards`` expert-parallel
    shards balancing *measured* load, via the chunked-exact LPT engine
    from ``core/balancing_vec.py`` (token-to-expert routing is the
    paper's imbalanced-assignment problem one level down).

    ``expert_load``: [E] nonnegative loads (e.g. the ``expert_load``
    aux metric from ``moe_ffn``, or raw token counts).  Returns
    ``(assignment [E] int, shard_loads [n_shards] float)``.
    """
    from repro.core.balancing_vec import lpt_assign

    loads = np.asarray(expert_load, np.float64)
    if loads.ndim != 1 or n_shards < 1:
        raise ValueError(f"bad plan inputs: {loads.shape}, {n_shards}")
    order = np.argsort(-loads, kind="stable")
    assign_sorted, _, shard_loads = lpt_assign(loads[order], n_shards)
    assignment = np.empty(loads.size, np.int64)
    assignment[order] = assign_sorted
    return assignment, shard_loads
