"""Adaptive cost models: measured coefficients behind the analytic prior.

``AdaptiveCostModel`` wraps one phase's :class:`~repro.core.cost_model.
CostModel`: it starts on the analytic ``transformer_cost_coeffs`` prior
(derived once through ``llm_cost_model`` / ``encoder_cost_model`` -- the
single injection point), accumulates measured (features, wall-time)
samples through a :class:`~repro.telemetry.calibrate.PhaseCalibrator`,
and swaps in the fitted coefficients once their confidence passes the
threshold.  Consumers poll :meth:`current` each time they need f(S);
:attr:`version` bumps only when the swap would *change the plan* (the
balancing objective is scale-invariant, so only a material shift of the
quadratic/linear ratio ``lam = beta/alpha`` forces a re-plan).

``AdaptiveOrchestration`` bundles one adaptive model per training phase
(LLM backbone + every encoder) plus a shared
:class:`~repro.telemetry.trace.TraceBuffer`, and is what
``MLLMGlobalOrchestrator(adaptive=...)`` consumes: dispatcher cost
models are refreshed from it before every solve, phase plans are
stamped with its version (stale plan-ahead plans are re-planned), and
measured per-phase step times flow back in through
``observe`` / ``observe_straggler``.

``AdaptiveServingCostModel`` is the serving twin: it duck-types
:class:`~repro.core.cost_model.ServingCostModel` (the scheduler and
``assign_replicas`` call it directly) while re-fitting the per-modality
weights and the decode/prefill cost ratio from ``EngineReport``-level
prefill/decode wall times.  The backbone alpha/beta stay on the
scheduler's unit scale (alpha ~ 1 per token) so ``token_budget``
semantics never change -- calibration only moves the *ratios* the
admission decisions depend on.

Calibration changes only the plan, never the math: every consumer uses
these models to choose rearrangements/admissions, and the rearranged
payloads are consequence-invariant by construction (paper S3.3).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import (
    CostModel,
    ServingCostModel,
    encoder_cost_model,
    llm_cost_model,
)
from repro.telemetry.calibrate import (
    CoeffEstimate,
    PhaseCalibrator,
    ServingCalibrator,
)
from repro.telemetry.trace import PhaseSample, TraceBuffer

__all__ = [
    "AdaptiveCostModel",
    "AdaptiveOrchestration",
    "AdaptiveServingCostModel",
]


def _lam_differs(old: CostModel, new: CostModel, tol: float) -> bool:
    """Would swapping ``old`` for ``new`` change balancing decisions?

    The per-phase objective is invariant to scaling f, so only the
    quadratic/linear ratio matters."""
    lo, ln = old.lam, new.lam
    scale = max(abs(lo), abs(ln))
    if scale == 0:
        return False
    return abs(ln - lo) / scale > tol


class AdaptiveCostModel:
    """One phase's f(S): analytic prior -> calibrated coefficients."""

    def __init__(self, prior: CostModel, *, phase: str = "phase",
                 trace: TraceBuffer | None = None,
                 replan_tol: float = 0.05, **calibrator_kw) -> None:
        self.prior = prior
        self.phase = phase
        self.trace = trace
        self.replan_tol = replan_tol
        self.calibrator = PhaseCalibrator(prior, **calibrator_kw)
        self._current = prior
        self._version = 0

    # ------------------------------------------------------------------
    @property
    def version(self) -> int:
        """Bumped whenever :meth:`current`'s output changes materially
        (swap-in, drift re-fit, or a > ``replan_tol`` shift of lam)."""
        return self._version

    @property
    def calibrated(self) -> bool:
        return self.calibrator.calibrated

    @property
    def estimate(self) -> CoeffEstimate | None:
        return self.calibrator.estimate

    @property
    def drift_events(self) -> int:
        return self.calibrator.drift_events

    def current(self) -> CostModel:
        return self._current

    # ------------------------------------------------------------------
    def observe(self, features: np.ndarray, wall_ms, *, step: int = 0,
                shards: Sequence[int] | None = None) -> bool:
        """Feed measured (features, wall-time) rows; True on drift."""
        F = np.asarray(features, dtype=np.float64)
        if F.ndim == 1:
            F = F[None, :]
        w = np.atleast_1d(np.asarray(wall_ms, dtype=np.float64))
        if self.trace is not None:
            for i, (row, t) in enumerate(zip(F, w)):
                shard = shards[i] if shards is not None else i
                self.trace.add(PhaseSample(
                    phase=self.phase, shard=int(shard), step=step,
                    features=row, wall_ms=float(t), kind="exec"))
        drifted = self.calibrator.observe(F, w)
        cand = self.calibrator.cost_model()
        if drifted or _lam_differs(self._current, cand, self.replan_tol):
            self._current = cand
            self._version += 1
        return drifted

    def observe_straggler(self, features: np.ndarray, wall_ms: float, *,
                          step: int = 0) -> bool:
        """Attribute one synchronous-step wall time to the straggler.

        Under synchronous DP the measured step time is the *max* over
        shards, so the sample pairs the scalar time with the feature
        row the current model predicts most expensive."""
        F = np.asarray(features, dtype=np.float64)
        if F.ndim == 1:
            F = F[None, :]
        costs = self._current.cost_from_features(F)
        i = int(np.argmax(costs))
        return self.observe(F[i], float(wall_ms), step=step, shards=[i])

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able dynamic state: calibrator window + the currently
        served coefficients and version (the prior is reconstructed from
        the config at restore time, not serialized)."""
        return {
            "calibrator": self.calibrator.state_dict(),
            "alpha": self._current.alpha,
            "beta": self._current.beta,
            "version": self._version,
        }

    def load_state_dict(self, state) -> None:
        self.calibrator.load_state_dict(state["calibrator"])
        self._current = self.prior.with_coeffs(state["alpha"], state["beta"])
        self._version = int(state["version"])

    def summary(self) -> dict:
        est = self.estimate
        return {
            "phase": self.phase,
            "prior_alpha": self.prior.alpha,
            "prior_beta": self.prior.beta,
            "alpha": self._current.alpha,
            "beta": self._current.beta,
            "calibrated": self.calibrated,
            "version": self._version,
            "drift_events": self.drift_events,
            "n_samples": self.calibrator.n_observed,
            "rel_se": est.max_rel_se() if est is not None else None,
        }


class AdaptiveOrchestration:
    """Per-phase adaptive cost models for the training orchestrator."""

    def __init__(self, cfg=None, *, priors: Mapping[str, CostModel] | None = None,
                 trace_capacity: int = 8192, replan_tol: float = 0.05,
                 **calibrator_kw) -> None:
        if cfg is None and priors is None:
            raise ValueError("need a ModelConfig or explicit per-phase priors")
        self.trace = TraceBuffer(trace_capacity)
        phase_priors: dict[str, CostModel] = {}
        if cfg is not None:
            phase_priors["llm"] = llm_cost_model(cfg)
            for e in cfg.encoders:
                phase_priors[e.name] = encoder_cost_model(e)
        if priors:
            phase_priors.update(priors)
        self.models = {
            name: AdaptiveCostModel(prior, phase=name, trace=self.trace,
                                    replan_tol=replan_tol, **calibrator_kw)
            for name, prior in phase_priors.items()
        }
        self._step = 0

    # ------------------------------------------------------------------
    @property
    def version(self) -> int:
        return sum(m.version for m in self.models.values())

    @property
    def drift_events(self) -> int:
        return sum(m.drift_events for m in self.models.values())

    @property
    def calibrated(self) -> bool:
        return all(m.calibrated for m in self.models.values())

    def cost_model(self, phase: str) -> CostModel:
        return self.models[phase].current()

    # ------------------------------------------------------------------
    def observe(self, features_by_phase: Mapping[str, np.ndarray],
                times_by_phase: Mapping[str, "float | np.ndarray"], *,
                step: int | None = None) -> dict[str, bool]:
        """Feed one step's measured phase times.

        ``times_by_phase[p]`` is either a per-shard vector matched to
        ``features_by_phase[p]`` rows, or a scalar synchronous step time
        (attributed to the straggler shard).  Phases without a time are
        skipped.  Returns the per-phase drift flags."""
        if step is None:
            step = self._step
        self._step = step + 1
        out: dict[str, bool] = {}
        for phase, t in times_by_phase.items():
            if phase not in self.models:
                continue
            F = np.asarray(features_by_phase[phase], dtype=np.float64)
            t_arr = np.atleast_1d(np.asarray(t, dtype=np.float64))
            m = self.models[phase]
            if t_arr.size == 1 and F.ndim == 2 and F.shape[0] > 1:
                out[phase] = m.observe_straggler(F, float(t_arr[0]), step=step)
            else:
                out[phase] = m.observe(F, t_arr, step=step)
        return out

    def record_plan_spans(self, phase_solve_ms: Mapping[str, float], *,
                          step: int | None = None) -> None:
        """Host dispatcher spans -> the trace (never used for fitting).

        Defaults to the shared observation step counter (without
        advancing it), so plan spans and exec samples line up."""
        if step is None:
            step = self._step
        for phase, ms in phase_solve_ms.items():
            self.trace.add(PhaseSample(
                phase=phase, shard=0, step=step,
                features=np.zeros(4), wall_ms=float(ms), kind="plan"))

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able calibration state for all phases (the trace ring is
        diagnostic telemetry and deliberately NOT checkpointed)."""
        return {
            "step": self._step,
            "models": {n: m.state_dict() for n, m in self.models.items()},
        }

    def load_state_dict(self, state) -> None:
        self._step = int(state["step"])
        for name, sub in state["models"].items():
            if name in self.models:
                self.models[name].load_state_dict(sub)

    def summary(self) -> dict[str, dict]:
        return {name: m.summary() for name, m in self.models.items()}

    def export_chrome_trace(self, path) -> None:
        self.trace.export_chrome_trace(path)


class AdaptiveServingCostModel:
    """Serving admission costs with measured modality weights.

    Duck-types :class:`~repro.core.cost_model.ServingCostModel`
    (``model`` / ``modality_weights`` / ``decode_cost`` /
    ``weighted_length[s]`` / ``prefill_cost``), so it drops into
    :class:`~repro.serving.engine.scheduler.Scheduler` and
    ``assign_replicas`` unchanged.  The engine feeds it per-call
    prefill/decode wall times; once the fit is confident the calibrated
    weights replace the analytic ones.  The backbone alpha/beta are kept
    from the prior: the budget is denominated in "text-token units" and
    calibration must not silently rescale it."""

    def __init__(self, prior: ServingCostModel, *,
                 trace: TraceBuffer | None = None,
                 replan_tol: float = 0.05, **calibrator_kw) -> None:
        self.prior = prior
        self.trace = trace
        self.replan_tol = replan_tol
        self.calibrator = ServingCalibrator(
            tuple(prior.modality_weights), **calibrator_kw)
        self._current = prior
        self._version = 0
        self._n_prefill = 0
        self._n_decode = 0

    # -- ServingCostModel interface -------------------------------------
    @property
    def model(self) -> CostModel:
        return self._current.model

    @property
    def modality_weights(self) -> Mapping[str, float]:
        return self._current.modality_weights

    @property
    def decode_cost(self) -> float:
        return self._current.decode_cost

    def weighted_length(self, text_len, modality_tokens=None) -> float:
        return self._current.weighted_length(text_len, modality_tokens)

    def prefill_cost(self, text_len, modality_tokens=None) -> float:
        return self._current.prefill_cost(text_len, modality_tokens)

    def weighted_lengths(self, text_lens, modality_tokens) -> np.ndarray:
        return self._current.weighted_lengths(text_lens, modality_tokens)

    # -- calibration ----------------------------------------------------
    @property
    def version(self) -> int:
        return self._version

    @property
    def calibrated(self) -> bool:
        return self.calibrator.calibrated

    @property
    def drift_events(self) -> int:
        return self.calibrator.drift_events

    def current(self) -> ServingCostModel:
        return self._current

    def observe_prefill(self, token_counts: Mapping[str, int],
                        wall_ms: float, *, step: int = 0) -> bool:
        if self.trace is not None:
            n = float(sum(token_counts.values()))
            self.trace.add(PhaseSample(
                phase="serve_prefill", shard=0, step=step,
                features=np.array([n, 0.0, 0.0, 0.0]),
                wall_ms=float(wall_ms), kind="exec"))
        drifted = self.calibrator.observe_prefill(token_counts, wall_ms)
        self._refresh()
        return drifted

    def observe_decode(self, batch: int, wall_ms: float, *,
                       step: int = 0) -> None:
        if self.trace is not None:
            self.trace.add(PhaseSample(
                phase="serve_decode", shard=0, step=step,
                features=np.array([float(batch), 0.0, 0.0, 0.0]),
                wall_ms=float(wall_ms), kind="exec"))
        self.calibrator.observe_decode(batch, wall_ms)
        self._refresh()

    def _refresh(self) -> None:
        weights = self.calibrator.weights()
        if weights is None:
            return
        merged = dict(self.prior.modality_weights)
        merged.update(weights)
        dec = self.calibrator.decode_cost()
        cand = dataclasses.replace(
            self.prior, modality_weights=merged,
            decode_cost=self.prior.decode_cost if dec is None else dec)
        if self._weights_differ(self._current, cand):
            self._current = cand
            self._version += 1

    def _weights_differ(self, old: ServingCostModel,
                        new: ServingCostModel) -> bool:
        for m in new.modality_weights:
            ow = old.modality_weights.get(m, 1.0)
            nw = new.modality_weights[m]
            if abs(nw - ow) / max(abs(ow), abs(nw), 1e-12) > self.replan_tol:
                return True
        od, nd = old.decode_cost, new.decode_cost
        return abs(nd - od) / max(abs(od), abs(nd), 1e-12) > self.replan_tol

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able dynamic state (serving replica handoff /
        checkpoint): calibrator window + currently served weights."""
        return {
            "calibrator": self.calibrator.state_dict(),
            "modality_weights": dict(self._current.modality_weights),
            "decode_cost": self._current.decode_cost,
            "version": self._version,
        }

    def load_state_dict(self, state) -> None:
        self.calibrator.load_state_dict(state["calibrator"])
        self._current = dataclasses.replace(
            self.prior,
            modality_weights=dict(state["modality_weights"]),
            decode_cost=float(state["decode_cost"]))
        self._version = int(state["version"])

    def summary(self) -> dict:
        return {
            "calibrated": self.calibrated,
            "version": self._version,
            "drift_events": self.drift_events,
            "prior_weights": dict(self.prior.modality_weights),
            "weights": dict(self._current.modality_weights),
            "prior_decode_cost": self.prior.decode_cost,
            "decode_cost": self._current.decode_cost,
        }
