"""Online cost-model calibration: fit f(S) coefficients from traces.

Design note.  The balancing objective only ever consumes *ratios* of
costs (utilization = mean/max, argmin over rearrangements), so fitting
wall-clock milliseconds directly onto the feature basis

    t_phase(S) ~ alpha * x0(S) + beta * x_quad(S)

gives coefficients that are immediately usable as a
:class:`~repro.core.cost_model.CostModel` -- no unit conversion.  Both
coefficients are physically nonnegative, which is exactly what makes a
mis-fit dangerous if unconstrained least squares were used (a noisy
window can produce beta < 0 and *invert* the balancing preference for
long sequences); hence every solve here is a **regularized NNLS**:

    min_{c >= 0}  ||X c - y||^2 + ridge * ||c - c_prior||^2

with the analytic ``transformer_cost_coeffs`` prior as the regularizer
target, so one noisy sample cannot yank the model and zero samples
reproduce the prior exactly.  :class:`RecursiveFit` is the O(d^2)
sliding-memory variant (projected recursive least squares with
exponential forgetting) for consumers that cannot afford the window
refit.

Drift.  Workload regime changes (a resolution bump, a new trace mix, a
different accelerator) shift the true coefficients; a fit over a window
straddling the change is wrong for *both* regimes.
:class:`DriftDetector` runs a two-sided CUSUM over standardized
relative residuals of the *current* estimate: it stays quiet under
stationary noise (the slack ``k`` absorbs it) but accumulates once the
mean residual shifts, and fires a drift event that tells the calibrator
to flush its pre-change window and re-converge.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Mapping, Sequence

import numpy as np

from repro.core.cost_model import CostModel, N_FEATURES

try:  # scipy's Lawson-Hanson NNLS when available (CI installs it)
    from scipy.optimize import nnls as _scipy_nnls
except Exception:  # pragma: no cover - exercised in bare containers
    _scipy_nnls = None

__all__ = [
    "CoeffEstimate",
    "DriftDetector",
    "PhaseCalibrator",
    "RecursiveFit",
    "ServingCalibrator",
    "nnls_fit",
]


# ---------------------------------------------------------------------------
# NNLS core


def _nnls_active_set(A: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Exact small-dimension NNLS by active-set enumeration.

    The calibrator fits 2-4 coefficients, so enumerating all 2^k
    support sets and keeping the best feasible least-squares solution is
    exact and allocation-free -- the fallback when scipy is absent."""
    k = A.shape[1]
    best = np.zeros(k)
    best_rss = float(b @ b)
    for mask in range(1, 1 << k):
        cols = [j for j in range(k) if mask >> j & 1]
        sol, *_ = np.linalg.lstsq(A[:, cols], b, rcond=None)
        if (sol < 0).any():
            continue
        r = b - A[:, cols] @ sol
        rss = float(r @ r)
        if rss < best_rss - 1e-12 * max(1.0, best_rss):
            best_rss = rss
            best = np.zeros(k)
            best[cols] = sol
    return best


def nnls_fit(X: np.ndarray, y: np.ndarray, *, ridge: float = 0.0,
             prior: Sequence[float] | None = None) -> np.ndarray:
    """Regularized nonnegative least squares.

    Solves ``min_{c>=0} ||Xc - y||^2 + ridge*||c - prior||^2`` by row
    augmentation.  Columns are rescaled to unit RMS internally (the
    quadratic features dwarf the linear ones by orders of magnitude) so
    the solve is well conditioned; coefficients come back in the
    original units."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64).reshape(-1)
    if X.ndim != 2 or X.shape[0] != y.size:
        raise ValueError(f"shape mismatch: X {X.shape} vs y {y.shape}")
    n, k = X.shape
    prior_v = (np.zeros(k) if prior is None
               else np.asarray(prior, dtype=np.float64).reshape(k))
    if (prior_v < 0).any():
        raise ValueError("prior must be nonnegative")
    if n == 0:
        return prior_v.copy()
    scale = np.sqrt(np.mean(X * X, axis=0))
    scale[scale == 0] = 1.0
    Xs = X / scale
    A, b = Xs, y
    if ridge > 0:
        A = np.vstack([Xs, math.sqrt(ridge) * np.eye(k)])
        b = np.concatenate([y, math.sqrt(ridge) * prior_v * scale])
    if _scipy_nnls is not None:
        c, _ = _scipy_nnls(A, b)
    else:
        c = _nnls_active_set(A, b)
    return c / scale


@dataclasses.dataclass(frozen=True)
class CoeffEstimate:
    """A fitted (alpha, beta) with its uncertainty.

    Standard errors come from the Gaussian approximation at the NNLS
    solution (sigma^2 * (X'X + ridge I)^-1).  ``alpha_rel`` /
    ``beta_rel`` measure each coefficient's uncertainty by its *impact*:
    the share of the window's typical predicted cost that one standard
    error of the coefficient moves.  This is what makes a genuinely
    linear phase (true beta = 0, e.g. SSM) calibratable: beta pinned at
    the NNLS boundary has negligible cost impact even though its
    coefficient-relative error is undefined."""

    alpha: float
    beta: float
    alpha_se: float
    beta_se: float
    n: int
    sigma: float  # residual std in wall-ms units
    quad_index: int
    alpha_rel: float = np.inf  # alpha_se * typ(x0) / typ(predicted cost)
    beta_rel: float = np.inf  # beta_se * typ(xq) / typ(predicted cost)

    def max_rel_se(self) -> float:
        return max(self.alpha_rel, self.beta_rel)

    def confident(self, rel_tol: float) -> bool:
        return self.n >= 2 and self.max_rel_se() <= rel_tol

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Mapping) -> "CoeffEstimate":
        return CoeffEstimate(**dict(d))


def fit_phase_coeffs(X: np.ndarray, y: np.ndarray, *, quad_index: int,
                     ridge: float = 1e-3,
                     prior: tuple[float, float] = (1.0, 0.0)) -> CoeffEstimate:
    """Fit (alpha, beta) of one phase from (n, 4) features + wall times."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64).reshape(-1)
    cols = X[:, [0, quad_index]]
    c = nnls_fit(cols, y, ridge=ridge, prior=prior)
    n, k = cols.shape
    resid = y - cols @ c
    dof = max(n - k, 1)
    sigma2 = float(resid @ resid) / dof
    scale = np.sqrt(np.mean(cols * cols, axis=0))
    scale[scale == 0] = 1.0
    G = (cols / scale).T @ (cols / scale) + ridge * np.eye(k)
    try:
        cov_s = sigma2 * np.linalg.inv(G)
        se = np.sqrt(np.maximum(np.diag(cov_s), 0.0)) / scale
    except np.linalg.LinAlgError:  # pragma: no cover
        se = np.full(k, np.inf)
    typical_cost = max(float(c @ scale), 1e-30)  # cost at the RMS batch
    rel = se * scale / typical_cost
    return CoeffEstimate(alpha=float(c[0]), beta=float(c[1]),
                         alpha_se=float(se[0]), beta_se=float(se[1]),
                         n=n, sigma=math.sqrt(sigma2), quad_index=quad_index,
                         alpha_rel=float(rel[0]), beta_rel=float(rel[1]))


# ---------------------------------------------------------------------------
# Recursive least squares (online variant)


class RecursiveFit:
    """Projected recursive least squares with exponential forgetting.

    O(d^2) per sample, no window storage: ``theta`` tracks the
    regularized LS solution and is projected onto the nonnegative
    orthant after every update (projected-RLS; for this well-posed
    2-4 dim problem the projection is the NNLS clip).  ``forget < 1``
    discounts old samples geometrically, giving the online fit a
    built-in drift response with time constant ``1/(1-forget)``."""

    def __init__(self, n_features: int = 2, *,
                 prior: Sequence[float] | None = None,
                 ridge: float = 1e-3, forget: float = 1.0) -> None:
        if not 0.0 < forget <= 1.0:
            raise ValueError("forget must be in (0, 1]")
        self.k = n_features
        self.theta = (np.zeros(n_features) if prior is None
                      else np.asarray(prior, dtype=np.float64).copy())
        self.P = np.eye(n_features) / max(ridge, 1e-12)
        self.forget = forget
        self.n = 0
        self._scale: np.ndarray | None = None

    def update(self, x: Sequence[float], y: float) -> float:
        """Consume one sample; returns the pre-update relative residual."""
        x = np.asarray(x, dtype=np.float64).reshape(self.k)
        if self._scale is None:
            s = np.abs(x)
            s[s == 0] = 1.0
            self._scale = s  # first-sample column scaling (conditioning)
        xs = x / self._scale
        pred = float(x @ self.theta)
        resid = (y - pred) / max(abs(pred), 1e-12)
        th_s = self.theta * self._scale
        Px = self.P @ xs
        denom = self.forget + float(xs @ Px)
        gain = Px / denom
        th_s = th_s + gain * (y - float(xs @ th_s))
        self.P = (self.P - np.outer(gain, Px)) / self.forget
        self.theta = np.maximum(th_s / self._scale, 0.0)
        self.n += 1
        return resid

    @property
    def coeffs(self) -> np.ndarray:
        return self.theta.copy()

    def state_dict(self) -> dict:
        """JSON-able dynamic state (checkpointing); hyperparameters are
        construction-time and not included."""
        return {
            "theta": self.theta.tolist(),
            "P": self.P.tolist(),
            "n": self.n,
            "scale": None if self._scale is None else self._scale.tolist(),
        }

    def load_state_dict(self, state: Mapping) -> None:
        self.theta = np.asarray(state["theta"], dtype=np.float64)
        self.P = np.asarray(state["P"], dtype=np.float64)
        self.n = int(state["n"])
        scale = state.get("scale")
        self._scale = None if scale is None else np.asarray(scale, np.float64)


# ---------------------------------------------------------------------------
# Drift detection


class DriftDetector:
    """Two-sided CUSUM over standardized relative residuals.

    ``warmup`` residuals establish the reference (mu0, sigma0); after
    that each residual's z-score feeds the classic tabular CUSUM

        S+ = max(0, S+ + z - k)      S- = max(0, S- - z - k)

    and a drift fires when either side exceeds ``h``.  With the default
    slack ``k = 0.75`` sigma and threshold ``h = 12`` sigma, stationary
    Gaussian noise has a vanishing false-alarm rate over thousands of
    samples, while a one-sigma mean shift is flagged in ~tens of
    samples.  After firing, the detector re-warms on the new regime."""

    def __init__(self, *, k: float = 0.75, h: float = 12.0,
                 warmup: int = 20, min_scale: float = 1e-4) -> None:
        self.k = k
        self.h = h
        self.warmup = warmup
        self.min_scale = min_scale
        self.events = 0
        self._reset()

    def _reset(self) -> None:
        self._ref: deque[float] = deque(maxlen=self.warmup)
        self._mu = 0.0
        self._sigma = 0.0
        self._armed = False
        self.s_pos = 0.0
        self.s_neg = 0.0

    def update(self, residual: float) -> bool:
        """Feed one relative residual; True when a drift event fires."""
        if not self._armed:
            self._ref.append(float(residual))
            if len(self._ref) == self.warmup:
                ref = np.asarray(self._ref)
                self._mu = float(ref.mean())
                self._sigma = max(float(ref.std()), self.min_scale)
                self._armed = True
            return False
        z = (float(residual) - self._mu) / self._sigma
        self.s_pos = max(0.0, self.s_pos + z - self.k)
        self.s_neg = max(0.0, self.s_neg - z - self.k)
        if self.s_pos > self.h or self.s_neg > self.h:
            self.events += 1
            self._reset()
            return True
        return False

    def state_dict(self) -> dict:
        """JSON-able dynamic state: reference window + CUSUM sums."""
        return {
            "events": self.events,
            "ref": list(self._ref),
            "mu": self._mu,
            "sigma": self._sigma,
            "armed": self._armed,
            "s_pos": self.s_pos,
            "s_neg": self.s_neg,
        }

    def load_state_dict(self, state: Mapping) -> None:
        self._reset()
        self.events = int(state["events"])
        self._ref.extend(float(x) for x in state["ref"])
        self._mu = float(state["mu"])
        self._sigma = float(state["sigma"])
        self._armed = bool(state["armed"])
        self.s_pos = float(state["s_pos"])
        self.s_neg = float(state["s_neg"])


# ---------------------------------------------------------------------------
# Per-phase calibration


class PhaseCalibrator:
    """Sliding-window regularized-NNLS calibration of one phase's f(S).

    ``observe`` consumes (features, wall_ms) rows; every ``refit_every``
    rows the window is refit and the estimate refreshed.  Residuals are
    only fed to the drift detector once the estimate is confident (the
    prior being 3x off is *mis-calibration*, which the fit repairs --
    not drift).  On drift the pre-change window is flushed down to the
    most recent ``drift_keep`` rows (they already belong to the new
    regime: CUSUM fires with a short delay) and the estimate is marked
    stale until the fit re-converges."""

    def __init__(self, prior: CostModel, *, window: int = 256,
                 min_samples: int = 12, refit_every: int = 4,
                 ridge: float = 1e-3, rel_tol: float = 0.25,
                 drift_keep: int = 16,
                 detector: DriftDetector | None = None) -> None:
        self.prior = prior
        self.window = window
        self.min_samples = min_samples
        self.refit_every = refit_every
        self.ridge = ridge
        self.rel_tol = rel_tol
        self.drift_keep = drift_keep
        self.detector = detector or DriftDetector()
        self._X: deque[np.ndarray] = deque(maxlen=window)
        self._y: deque[float] = deque(maxlen=window)
        self._since_refit = 0
        self._estimate: CoeffEstimate | None = None
        self._confident: CoeffEstimate | None = None  # last CONFIDENT fit
        self._stale = False
        self.n_observed = 0
        self.drift_events = 0

    # ------------------------------------------------------------------
    @property
    def estimate(self) -> CoeffEstimate | None:
        return self._estimate

    @property
    def calibrated(self) -> bool:
        """A confident fit exists and predates no drift."""
        return self._confident is not None and not self._stale

    def cost_model(self) -> CostModel:
        """Current best f(S): the last CONFIDENT fit once one exists
        (kept while re-converging after drift -- an unconfident
        post-drift refit is never served), the analytic prior before
        that."""
        if self._confident is not None:
            return self.prior.with_coeffs(self._confident.alpha,
                                          self._confident.beta)
        return self.prior

    # ------------------------------------------------------------------
    def observe(self, features: np.ndarray, wall_ms) -> bool:
        """Add sample rows; returns True if a drift event fired."""
        F = np.asarray(features, dtype=np.float64)
        if F.ndim == 1:
            F = F[None, :]
        w = np.atleast_1d(np.asarray(wall_ms, dtype=np.float64))
        if F.shape[0] != w.size or F.shape[1] != N_FEATURES:
            raise ValueError(f"features {F.shape} vs wall_ms {w.shape}")
        drifted = False
        cm = self.cost_model()
        feed_detector = self.calibrated  # never learn a reference off the
        for row, t in zip(F, w):         # (possibly 3x-off) analytic prior
            if feed_detector:
                pred = float(cm.cost_from_features(row))
                resid = (t - pred) / max(abs(pred), 1e-12)
                if self.detector.update(resid):
                    drifted = True
            self._X.append(row)
            self._y.append(float(t))
            self.n_observed += 1
            self._since_refit += 1
        if drifted:
            self._on_drift()
        elif (self._since_refit >= self.refit_every
                and len(self._y) >= min(self.min_samples, self.window)):
            self._refit()
        return drifted

    def _on_drift(self) -> None:
        self.drift_events += 1
        keep = min(self.drift_keep, len(self._y))
        X = list(self._X)[-keep:]
        y = list(self._y)[-keep:]
        self._X.clear()
        self._y.clear()
        self._X.extend(X)
        self._y.extend(y)
        self._stale = True
        self._since_refit = 0

    def _refit(self) -> None:
        X = np.stack(self._X)
        y = np.asarray(self._y)
        est = fit_phase_coeffs(
            X, y, quad_index=self.prior.quad_index, ridge=self.ridge,
            prior=(self.prior.alpha, self.prior.beta))
        self._estimate = est
        self._since_refit = 0
        if est.n >= self.min_samples and est.confident(self.rel_tol):
            self._confident = est
            self._stale = False

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able dynamic state: sample window, fit, CUSUM buffers."""
        return {
            "X": [row.tolist() for row in self._X],
            "y": list(self._y),
            "since_refit": self._since_refit,
            "estimate": None if self._estimate is None
            else self._estimate.to_json(),
            "confident": None if self._confident is None
            else self._confident.to_json(),
            "stale": self._stale,
            "n_observed": self.n_observed,
            "drift_events": self.drift_events,
            "detector": self.detector.state_dict(),
        }

    def load_state_dict(self, state: Mapping) -> None:
        self._X.clear()
        self._y.clear()
        self._X.extend(np.asarray(r, np.float64) for r in state["X"])
        self._y.extend(float(t) for t in state["y"])
        self._since_refit = int(state["since_refit"])
        est, conf = state.get("estimate"), state.get("confident")
        self._estimate = None if est is None else CoeffEstimate.from_json(est)
        self._confident = (None if conf is None
                           else CoeffEstimate.from_json(conf))
        self._stale = bool(state["stale"])
        self.n_observed = int(state["n_observed"])
        self.drift_events = int(state["drift_events"])
        self.detector.load_state_dict(state["detector"])


# ---------------------------------------------------------------------------
# Serving-side calibration (modality weights + decode cost)


class ServingCalibrator:
    """Fit per-modality serving weights and the decode/prefill ratio.

    Prefill model: one prefill batch's wall time is linear in its token
    composition, ``t ~ c_text*n_text + sum_m c_m*n_m`` (NNLS over the
    fixed modality column order), so the calibrated modality weight is
    ``c_m / c_text`` -- the measured per-token compute of a modality-m
    LLM token relative to a text token, exactly what
    :class:`~repro.core.cost_model.ServingCostModel` consumes.

    Decode model: ``t ~ c_dec * batch`` (slope through the origin), and
    the calibrated ``decode_cost`` is ``c_dec / c_text`` -- pricing one
    decoded token against one prefilled text token in the scheduler's
    shared admission budget."""

    def __init__(self, modalities: Sequence[str], *, window: int = 256,
                 min_samples: int = 8, refit_every: int = 4,
                 ridge: float = 1e-3, rel_tol: float = 0.35,
                 detector: DriftDetector | None = None) -> None:
        self.modalities = tuple(modalities)
        self.window = window
        self.min_samples = min_samples
        self.refit_every = refit_every
        self.ridge = ridge
        self.rel_tol = rel_tol
        self.detector = detector or DriftDetector()
        self._rows: deque[np.ndarray] = deque(maxlen=window)
        self._t: deque[float] = deque(maxlen=window)
        self._since_refit = 0
        self._dec: deque[tuple[float, float]] = deque(maxlen=window)
        self._coeffs: np.ndarray | None = None  # [c_text, c_m...]
        self._coeffs_se: np.ndarray | None = None
        self._dec_cost: float | None = None
        self.drift_events = 0

    # ------------------------------------------------------------------
    def observe_prefill(self, token_counts: Mapping[str, int],
                        wall_ms: float) -> bool:
        """One prefill sub-batch: its total token composition + time."""
        row = np.array(
            [float(token_counts.get("text", 0))]
            + [float(token_counts.get(m, 0)) for m in self.modalities])
        drifted = False
        if self._coeffs is not None:
            pred = float(row @ self._coeffs)
            resid = (wall_ms - pred) / max(abs(pred), 1e-12)
            if self.detector.update(resid):
                drifted = True
                self.drift_events += 1
                self._rows.clear()
                self._t.clear()
                self._coeffs = None
                # Pre-drift decode timings are the old regime too.
                self._dec.clear()
                self._dec_cost = None
        self._rows.append(row)
        self._t.append(float(wall_ms))
        self._since_refit += 1
        # Refit on the hot serving path only every refit_every samples
        # (plus immediately at min_samples and after a drift flush).
        if len(self._t) >= self.min_samples and (
                self._since_refit >= self.refit_every
                or self._coeffs is None):
            self._refit()
            self._since_refit = 0
        return drifted

    def observe_decode(self, batch: int, wall_ms: float) -> None:
        self._dec.append((float(batch), float(wall_ms)))
        b = np.array([x for x, _ in self._dec])
        t = np.array([x for _, x in self._dec])
        denom = float(b @ b)
        if denom > 0:
            self._dec_cost = float(b @ t) / denom

    def _refit(self) -> None:
        X = np.stack(self._rows)
        y = np.asarray(self._t)
        used = X.any(axis=0)  # modalities never seen stay at prior weight
        c = np.zeros(X.shape[1])
        c[used] = nnls_fit(X[:, used], y, ridge=self.ridge,
                           prior=np.zeros(int(used.sum())))
        self._coeffs = c
        resid = y - X @ c
        dof = max(y.size - int(used.sum()), 1)
        sigma2 = float(resid @ resid) / dof
        se = np.full(X.shape[1], np.inf)
        scale = np.sqrt(np.mean(X[:, used] ** 2, axis=0))
        scale[scale == 0] = 1.0
        G = (X[:, used] / scale).T @ (X[:, used] / scale) \
            + self.ridge * np.eye(int(used.sum()))
        try:
            se[used] = np.sqrt(np.maximum(
                np.diag(sigma2 * np.linalg.inv(G)), 0.0)) / scale
        except np.linalg.LinAlgError:  # pragma: no cover
            pass
        self._coeffs_se = se

    # ------------------------------------------------------------------
    @property
    def calibrated(self) -> bool:
        if self._coeffs is None or self._coeffs[0] <= 0:
            return False
        rel = self._coeffs_se[0] / self._coeffs[0]
        return len(self._t) >= self.min_samples and rel <= self.rel_tol

    def weights(self) -> dict[str, float] | None:
        """Calibrated modality weights (None until confident); modality
        columns with no observations are omitted (prior weight kept)."""
        if not self.calibrated:
            return None
        c_text = self._coeffs[0]
        out = {}
        for i, m in enumerate(self.modalities):
            c = self._coeffs[1 + i]
            if np.isfinite(self._coeffs_se[1 + i]):
                out[m] = float(c / c_text)
        return out

    def decode_cost(self) -> float | None:
        """Calibrated decode cost in prefill-text-token units."""
        if not self.calibrated or self._dec_cost is None:
            return None
        if len(self._dec) < self.min_samples:
            return None
        return float(self._dec_cost / self._coeffs[0])

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able dynamic state of the serving fit."""
        return {
            "rows": [row.tolist() for row in self._rows],
            "t": list(self._t),
            "since_refit": self._since_refit,
            "dec": [list(x) for x in self._dec],
            "coeffs": None if self._coeffs is None else self._coeffs.tolist(),
            "coeffs_se": (None if self._coeffs_se is None
                          else self._coeffs_se.tolist()),
            "dec_cost": self._dec_cost,
            "drift_events": self.drift_events,
            "detector": self.detector.state_dict(),
        }

    def load_state_dict(self, state: Mapping) -> None:
        self._rows.clear()
        self._t.clear()
        self._dec.clear()
        self._rows.extend(np.asarray(r, np.float64) for r in state["rows"])
        self._t.extend(float(t) for t in state["t"])
        self._dec.extend((float(b), float(t)) for b, t in state["dec"])
        c, se = state.get("coeffs"), state.get("coeffs_se")
        self._coeffs = None if c is None else np.asarray(c, np.float64)
        self._coeffs_se = None if se is None else np.asarray(se, np.float64)
        dc = state.get("dec_cost")
        self._dec_cost = None if dc is None else float(dc)
        self._since_refit = int(state["since_refit"])
        self.drift_events = int(state["drift_events"])
        self.detector.load_state_dict(state["detector"])
