"""Low-overhead per-phase, per-DP-shard trace capture.

Every balancing decision in this repo prices work through ``f(S)``
(:mod:`repro.core.cost_model`); this module records what the hardware
*actually* did so :mod:`repro.telemetry.calibrate` can close the loop.

A :class:`PhaseSample` pairs one mini-batch's feature vector

    [L, L^2/b, sum(l^2), b*max(l)^2]

(the shared basis of every f(S) variant -- see
``cost_model.FEATURE_NAMES``) with the measured wall time of executing
that batch on its shard.  Samples land in a fixed-capacity
:class:`TraceBuffer` ring (O(1) append, no allocation churn on the hot
path, oldest samples evicted), which can

  * hand the calibrator its (X, y) regression window
    (:meth:`TraceBuffer.design_matrix`), and
  * export a Chrome-trace / Perfetto JSON timeline
    (:meth:`TraceBuffer.export_chrome_trace`; open in ``ui.perfetto.dev``
    or ``chrome://tracing``) with one track per (phase, shard) and the
    host-side dispatcher spans alongside the device phase spans.

Sample *kinds* separate the two time domains:

  ``exec``  device execution of one phase's mini-batch (feeds calibration)
  ``plan``  host dispatcher/composition time (``PhasePlans`` accounting;
            never used for coefficient fitting, but visible in the trace)
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Iterable

import numpy as np

from repro.core.cost_model import FEATURE_NAMES, N_FEATURES, length_features

__all__ = ["PhaseSample", "TraceBuffer"]


@dataclasses.dataclass(frozen=True)
class PhaseSample:
    """One (phase, shard) observation: features + measured wall time."""

    phase: str
    shard: int
    step: int
    features: np.ndarray  # (N_FEATURES,) float64
    wall_ms: float
    kind: str = "exec"  # "exec" (device phase) | "plan" (host dispatcher)
    ts_ms: float | None = None  # optional start timestamp (trace export)

    def __post_init__(self) -> None:
        f = np.asarray(self.features, dtype=np.float64).reshape(-1)
        if f.size != N_FEATURES:
            raise ValueError(
                f"features must have {N_FEATURES} entries {FEATURE_NAMES}, "
                f"got shape {f.shape}")
        object.__setattr__(self, "features", f)

    @classmethod
    def from_lengths(cls, phase: str, lengths, wall_ms: float, *,
                     shard: int = 0, step: int = 0, padding: bool = False,
                     kind: str = "exec", ts_ms: float | None = None,
                     ) -> "PhaseSample":
        return cls(phase=phase, shard=shard, step=step,
                   features=length_features(lengths, padding),
                   wall_ms=float(wall_ms), kind=kind, ts_ms=ts_ms)


class TraceBuffer:
    """Fixed-capacity ring buffer of :class:`PhaseSample`.

    Thread-safe: the plan-ahead worker records host dispatcher spans
    while the consumer thread records measured phase times, so the ring
    pointer update and snapshot reads are taken under a lock."""

    def __init__(self, capacity: int = 4096) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._buf: list[PhaseSample | None] = [None] * capacity
        self._next = 0  # next write slot
        self._count = 0  # total samples ever added
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return min(self._count, self.capacity)

    @property
    def total_added(self) -> int:
        return self._count

    @property
    def dropped(self) -> int:
        """Samples evicted by the ring (capacity overflow)."""
        return max(0, self._count - self.capacity)

    def add(self, sample: PhaseSample) -> None:
        with self._lock:
            self._buf[self._next] = sample
            self._next = (self._next + 1) % self.capacity
            self._count += 1

    def extend(self, samples: Iterable[PhaseSample]) -> None:
        for s in samples:
            self.add(s)

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._next = 0
            self._count = 0

    def samples(self, phase: str | None = None,
                kind: str | None = None) -> list[PhaseSample]:
        """Oldest-first view, optionally filtered."""
        with self._lock:
            if self._count < self.capacity:
                ordered = self._buf[: self._count]
            else:
                ordered = self._buf[self._next:] + self._buf[: self._next]
        return [s for s in ordered
                if s is not None
                and (phase is None or s.phase == phase)
                and (kind is None or s.kind == kind)]

    def phases(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self.samples():
            seen.setdefault(s.phase, None)
        return list(seen)

    # ------------------------------------------------------------------
    def design_matrix(self, phase: str, *, kind: str = "exec",
                      ) -> tuple[np.ndarray, np.ndarray]:
        """(X, y) for the calibrator: X (n, 4) features, y (n,) wall ms."""
        sel = self.samples(phase, kind)
        if not sel:
            return np.zeros((0, N_FEATURES)), np.zeros(0)
        X = np.stack([s.features for s in sel])
        y = np.array([s.wall_ms for s in sel], dtype=np.float64)
        return X, y

    # ------------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome-trace ("traceEvents") JSON object.

        One pid per phase, one tid per shard; ``exec`` samples become
        complete ("X") events.  Samples without an explicit ``ts_ms``
        are laid out back-to-back per (phase, shard) track in arrival
        order, so relative durations stay meaningful even when the
        producer never recorded absolute timestamps.
        """
        events: list[dict] = []
        pids: dict[str, int] = {}
        cursor: dict[tuple[str, int], float] = {}
        for s in self.samples():
            pid = pids.setdefault(s.phase, len(pids) + 1)
            key = (s.phase, s.shard)
            if s.ts_ms is not None:
                ts = s.ts_ms
                cursor[key] = max(cursor.get(key, 0.0), ts + s.wall_ms)
            else:
                ts = cursor.get(key, 0.0)
                cursor[key] = ts + s.wall_ms
            events.append({
                "name": f"{s.phase}/{s.kind}",
                "cat": s.kind,
                "ph": "X",
                "pid": pid,
                "tid": s.shard,
                "ts": ts * 1e3,  # chrome trace wants microseconds
                "dur": s.wall_ms * 1e3,
                "args": {"step": s.step,
                         **{n: float(v)
                            for n, v in zip(FEATURE_NAMES, s.features)}},
            })
        for phase, pid in pids.items():
            events.append({"name": "process_name", "ph": "M", "pid": pid,
                           "args": {"name": f"phase:{phase}"}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
