"""Telemetry & online cost-model calibration (ISSUE 4).

Closes the loop from measured per-phase step times back into the f(S)
that every balancing decision optimizes:

    trace.py      PhaseSample ring buffer + Chrome-trace/Perfetto export
    calibrate.py  regularized NNLS / RLS coefficient fitting, confidence
                  intervals, CUSUM drift detection
    adaptive.py   AdaptiveCostModel / AdaptiveOrchestration /
                  AdaptiveServingCostModel -- analytic prior until the
                  fit is confident, calibrated coefficients after
"""
from repro.telemetry.adaptive import (
    AdaptiveCostModel,
    AdaptiveOrchestration,
    AdaptiveServingCostModel,
)
from repro.telemetry.calibrate import (
    CoeffEstimate,
    DriftDetector,
    PhaseCalibrator,
    RecursiveFit,
    ServingCalibrator,
    nnls_fit,
)
from repro.telemetry.trace import PhaseSample, TraceBuffer

__all__ = [
    "AdaptiveCostModel", "AdaptiveOrchestration", "AdaptiveServingCostModel",
    "CoeffEstimate", "DriftDetector", "PhaseCalibrator", "PhaseSample",
    "RecursiveFit", "ServingCalibrator", "TraceBuffer", "nnls_fit",
]
