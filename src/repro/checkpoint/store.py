"""Dependency-free sharded pytree checkpoint store.

A checkpoint is a *directory* of per-leaf ``.npy`` shards plus one
``manifest.json`` that carries everything needed to rebuild the pytree
on a host that knows nothing about the writer:

  * the tree structure (nested dict/list/tuple skeleton with leaf
    placeholders), so restore needs no live template,
  * per-leaf dtype/shape and a SHA-256 content hash (corruption is
    *detected*, never silently restored),
  * optional mesh / ``PartitionSpec`` metadata per leaf -- the writer
    records how the array was sharded so :mod:`repro.checkpoint.elastic`
    can re-shard it host-side onto a different mesh,
  * a free-form JSON ``extras`` blob (data cursor, calibrator state,
    step counter -- anything :mod:`repro.checkpoint.state` bundles).

Atomic commit protocol: everything is written into ``<name>.tmp``, every
file (and the directory entry) is fsynced, and only then is the
directory renamed to its final name.  A crash mid-save therefore leaves
either the previous complete checkpoint untouched plus a ``.tmp`` litter
directory (ignored and garbage-collected by the manager), or nothing --
never a half-written checkpoint under a committed name.

:class:`CheckpointManager` adds the step-numbered directory layout
(``step_000042/``), a keep-last-K retention policy, and restore-with-
fallback: a corrupt newest checkpoint is flagged (renamed to
``*.corrupt``) and the next older complete one is restored instead.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import re
import shutil
import time
from typing import Any, Iterator

import numpy as np

__all__ = [
    "CheckpointCorruptError",
    "CheckpointManager",
    "CheckpointOp",
    "LeafInfo",
    "load_manifest",
    "load_pytree",
    "save_pytree",
    "spec_to_meta",
]

MANIFEST = "manifest.json"
FORMAT_VERSION = 1
_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointCorruptError(RuntimeError):
    """A committed checkpoint failed structural or hash verification."""


# ---------------------------------------------------------------------------
# Tree <-> (skeleton, leaves)


def _is_container(node: Any) -> bool:
    # PartitionSpec subclasses tuple; a specs tree must treat it as a
    # leaf, not recurse into its axis entries.
    if type(node).__name__ == "PartitionSpec":
        return False
    return isinstance(node, (dict, list, tuple))


def _flatten(tree: Any, path: str = "") -> Iterator[tuple[str, Any]]:
    """Depth-first (path, leaf) pairs; paths are '/'-joined keys."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{path}/{k}" if path else str(k))
    elif _is_container(tree):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{path}/{i}" if path else str(i))
    else:
        yield path, tree


def _skeleton(tree: Any) -> Any:
    """JSON-able structure mirror with leaf markers."""
    if isinstance(tree, dict):
        items = {k: _skeleton(v) for k, v in tree.items()}
        return {"__kind__": "dict", "items": items}
    if _is_container(tree):
        kind = "list" if isinstance(tree, list) else "tuple"
        return {"__kind__": kind, "items": [_skeleton(v) for v in tree]}
    return {"__kind__": "leaf"}


def _unskeleton(skel: Any, path: str, leaves: dict[str, Any]) -> Any:
    kind = skel["__kind__"]
    if kind == "dict":
        return {
            k: _unskeleton(v, f"{path}/{k}" if path else str(k), leaves)
            for k, v in skel["items"].items()
        }
    if kind in ("list", "tuple"):
        seq = [
            _unskeleton(v, f"{path}/{i}" if path else str(i), leaves)
            for i, v in enumerate(skel["items"])
        ]
        return seq if kind == "list" else tuple(seq)
    return leaves[path]


def spec_to_meta(spec: Any) -> list[Any] | None:
    """A ``PartitionSpec`` (or tuple of axis names) as a JSON-able list.

    Entries are axis-name strings, lists of axis names, or ``None``.  A
    ``None`` spec maps to ``None`` (replicated / unsharded).
    """
    if spec is None:
        return None
    out: list[Any] = []
    for part in tuple(spec):
        if part is None or isinstance(part, str):
            out.append(part)
        else:
            out.append(list(part))
    return out


# ---------------------------------------------------------------------------
# Leaf I/O


@dataclasses.dataclass(frozen=True)
class LeafInfo:
    """One saved leaf's manifest row.

    ``packed`` marks leaves whose dtype ``.npy`` cannot represent
    natively (bfloat16, float8 -- the ml_dtypes extension types): the
    shard then holds the raw bytes as uint8 with a trailing itemsize
    dim, and ``dtype``/``shape`` record the logical view to rebuild.
    """

    path: str  # tree path ('params/llm/wte')
    file: str  # shard filename within the checkpoint dir
    dtype: str
    shape: tuple[int, ...]
    sha256: str
    spec: list[Any] | None = None  # PartitionSpec metadata (spec_to_meta)
    packed: bool = False

    def to_json(self) -> dict[str, Any]:
        return {
            "path": self.path,
            "file": self.file,
            "dtype": self.dtype,
            "shape": list(self.shape),
            "sha256": self.sha256,
            "spec": self.spec,
            "packed": self.packed,
        }

    @staticmethod
    def from_json(d: dict[str, Any]) -> "LeafInfo":
        return LeafInfo(
            path=d["path"],
            file=d["file"],
            dtype=d["dtype"],
            shape=tuple(d["shape"]),
            sha256=d["sha256"],
            spec=d.get("spec"),
            packed=bool(d.get("packed", False)),
        )


def _resolve_dtype(name: str) -> np.dtype:
    """Logical dtype by name, including ml_dtypes extension types."""
    try:
        return np.dtype(name)
    except TypeError:
        pass
    try:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))
    except (ImportError, AttributeError) as e:
        raise CheckpointCorruptError(
            f"checkpoint leaf dtype {name!r} needs ml_dtypes to restore"
        ) from e


def _leaf_filename(i: int, path: str) -> str:
    tail = re.sub(r"[^A-Za-z0-9_.-]+", "_", path)[-80:]
    return f"leaf_{i:05d}_{tail}.npy"


def _npy_bytes(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _write_file(path: str, data: bytes) -> None:
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


# ---------------------------------------------------------------------------
# Save / load


def save_pytree(
    path: str,
    tree: Any,
    *,
    specs: Any = None,
    extras: dict[str, Any] | None = None,
    meta: dict[str, Any] | None = None,
) -> str:
    """Atomically write ``tree`` as a checkpoint directory at ``path``.

    ``specs`` (optional) is a pytree of ``PartitionSpec``-likes congruent
    with (a prefix of) ``tree``; each leaf's spec is recorded in the
    manifest so an elastic restore can re-shard host-side.  ``extras`` is
    a JSON blob restored verbatim; ``meta`` adds top-level manifest keys
    (step, wall time, ...).  Returns the committed path.
    """
    final = os.path.abspath(path)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    spec_by_path: dict[str, Any] = {}
    if specs is not None:
        spec_by_path = dict(_flatten(specs))
    leaves: list[LeafInfo] = []
    for i, (leaf_path, value) in enumerate(_flatten(tree)):
        arr = np.asarray(value)
        # .npy cannot represent ml_dtypes extension types (bfloat16,
        # float8...): store their raw bytes and the logical view.
        packed = arr.dtype.kind == "V"
        stored = arr.view((np.uint8, (arr.dtype.itemsize,))) if packed else arr
        data = _npy_bytes(stored)
        fname = _leaf_filename(i, leaf_path)
        _write_file(os.path.join(tmp, fname), data)
        leaves.append(
            LeafInfo(
                path=leaf_path,
                file=fname,
                dtype=arr.dtype.name if packed else str(arr.dtype),
                shape=tuple(arr.shape),
                sha256=hashlib.sha256(data).hexdigest(),
                spec=spec_to_meta(spec_by_path.get(leaf_path)),
                packed=packed,
            )
        )
    manifest = {
        "format_version": FORMAT_VERSION,
        **(meta or {}),
        "tree": _skeleton(tree),
        "leaves": [leaf.to_json() for leaf in leaves],
        "extras": extras or {},
    }
    payload = json.dumps(manifest, indent=1, sort_keys=False).encode()
    _write_file(os.path.join(tmp, MANIFEST), payload)
    _fsync_dir(tmp)
    # Overwrite via rename-swap, not rmtree-then-rename: the previously
    # committed checkpoint is moved aside (a cheap rename) so the crash
    # window between losing the old name and committing the new one is
    # two metadata operations, with the old payload still on disk under
    # ``.old`` until the new one is in place.
    old = None
    if os.path.exists(final):
        old = final + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(final, old)
    os.rename(tmp, final)
    _fsync_dir(os.path.dirname(final) or ".")
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)
    return final


def load_manifest(path: str) -> dict[str, Any]:
    mpath = os.path.join(path, MANIFEST)
    if not os.path.isfile(mpath):
        raise CheckpointCorruptError(f"{path}: missing {MANIFEST}")
    try:
        with open(mpath, "rb") as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, OSError) as e:
        raise CheckpointCorruptError(f"{path}: unreadable manifest: {e}") from e
    if manifest.get("format_version") != FORMAT_VERSION:
        raise CheckpointCorruptError(
            f"{path}: format_version {manifest.get('format_version')!r} "
            f"!= {FORMAT_VERSION}"
        )
    return manifest


def load_pytree(path: str, *, verify: bool = True) -> tuple[Any, dict[str, Any]]:
    """Load a checkpoint directory -> (tree, manifest).

    With ``verify`` every shard's SHA-256 is recomputed and compared to
    the manifest; any mismatch (truncated file, bit rot, missing shard)
    raises :class:`CheckpointCorruptError`.
    """
    manifest = load_manifest(path)
    leaves: dict[str, np.ndarray] = {}
    for row in manifest["leaves"]:
        info = LeafInfo.from_json(row)
        fpath = os.path.join(path, info.file)
        if not os.path.isfile(fpath):
            raise CheckpointCorruptError(f"{path}: missing shard {info.file}")
        with open(fpath, "rb") as f:
            data = f.read()
        if verify and hashlib.sha256(data).hexdigest() != info.sha256:
            raise CheckpointCorruptError(
                f"{path}: shard {info.file} failed content hash "
                f"(truncated or corrupt)"
            )
        try:
            arr = np.load(io.BytesIO(data), allow_pickle=False)
        except ValueError as e:
            raise CheckpointCorruptError(
                f"{path}: shard {info.file} is not a readable .npy: {e}"
            ) from e
        if info.packed:
            logical = _resolve_dtype(info.dtype)
            expect = tuple(info.shape) + (logical.itemsize,)
            if arr.dtype != np.uint8 or tuple(arr.shape) != expect:
                raise CheckpointCorruptError(
                    f"{path}: packed shard {info.file} is "
                    f"{arr.dtype}{arr.shape}, expected uint8{expect}"
                )
            arr = arr.view(logical)[..., 0]
        if str(arr.dtype) != info.dtype or tuple(arr.shape) != info.shape:
            raise CheckpointCorruptError(
                f"{path}: shard {info.file} is {arr.dtype}{arr.shape}, "
                f"manifest says {info.dtype}{info.shape}"
            )
        leaves[info.path] = arr
    try:
        tree = _unskeleton(manifest["tree"], "", leaves)
    except KeyError as e:
        raise CheckpointCorruptError(
            f"{path}: manifest/shard mismatch: missing leaf {e}"
        ) from e
    return tree, manifest


# ---------------------------------------------------------------------------
# Step-numbered checkpoint directory with retention + fallback restore


@dataclasses.dataclass
class CheckpointOp:
    """One timed save/restore operation (observability attribution).

    ``start_s`` is the host monotonic-ish wall clock (``time.time``)
    when the op began; ``wall_ms`` its duration.  The op log feeds the
    Perfetto timeline's checkpoint track
    (:func:`repro.obs.timeline.build_timeline`) and the MFU-gap
    waterfall's ``checkpoint_stall`` component.
    """

    kind: str  # "save" | "restore"
    step: int  # checkpoint step (-1 when a restore found nothing)
    start_s: float
    wall_ms: float


class CheckpointManager:
    """``<root>/step_NNNNNN`` checkpoints with keep-last-K retention.

    ``save`` commits atomically and prunes; ``restore_latest`` walks
    committed checkpoints newest-first, *flags* any corrupt one by
    renaming it to ``step_NNNNNN.corrupt`` and falls back to the next
    older complete checkpoint.  ``.tmp`` directories (crash litter) are
    ignored by :meth:`steps` and removed on the next save.

    Every save/restore is timed into :attr:`ops` (and, when a
    ``metrics`` registry is attached, a ``ckpt_op_ms{op=...}``
    histogram) so checkpoint stalls are attributable instead of
    vanishing into the step time.
    """

    def __init__(self, root: str, *, keep_last: int = 3, metrics=None) -> None:
        if keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        self.root = os.path.abspath(root)
        self.keep_last = keep_last
        self.ops: list[CheckpointOp] = []
        self._h_op = None
        if metrics is not None:
            self._h_op = metrics.histogram(
                "ckpt_op_ms", "checkpoint save/restore wall time", labels=("op",)
            )
        os.makedirs(self.root, exist_ok=True)

    def _record_op(self, kind: str, step: int, start_s: float, t0: float) -> None:
        wall_ms = (time.perf_counter() - t0) * 1e3
        self.ops.append(
            CheckpointOp(kind=kind, step=step, start_s=start_s, wall_ms=wall_ms)
        )
        if self._h_op is not None:
            self._h_op.observe(wall_ms, op=kind)

    @property
    def last_op_ms(self) -> float:
        """Duration of the most recent save/restore (0 when none ran)."""
        return self.ops[-1].wall_ms if self.ops else 0.0

    # -- layout ---------------------------------------------------------
    def step_path(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:06d}")

    def steps(self) -> list[int]:
        """Committed checkpoint steps, ascending (tmp/corrupt excluded)."""
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and os.path.isdir(os.path.join(self.root, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    # -- save -----------------------------------------------------------
    def save(
        self,
        step: int,
        tree: Any,
        *,
        specs: Any = None,
        extras: dict[str, Any] | None = None,
        meta: dict[str, Any] | None = None,
    ) -> str:
        start_s, t0 = time.time(), time.perf_counter()
        self._collect_tmp_litter()
        path = save_pytree(
            self.step_path(step),
            tree,
            specs=specs,
            extras=extras,
            meta={"step": int(step), **(meta or {})},
        )
        self._prune()
        self._record_op("save", int(step), start_s, t0)
        return path

    def _collect_tmp_litter(self) -> None:
        for name in os.listdir(self.root):
            if name.endswith((".tmp", ".old")):
                shutil.rmtree(os.path.join(self.root, name), ignore_errors=True)

    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(self.step_path(s), ignore_errors=True)

    # -- restore --------------------------------------------------------
    def restore(self, step: int, *, verify: bool = True):
        start_s, t0 = time.time(), time.perf_counter()
        try:
            return load_pytree(self.step_path(step), verify=verify)
        finally:
            self._record_op("restore", int(step), start_s, t0)

    def restore_latest(self, *, verify: bool = True, on_corrupt: str = "flag"):
        """Newest complete checkpoint -> (tree, manifest), or ``None``
        when the root holds no restorable checkpoint.

        A corrupt candidate is skipped; with ``on_corrupt='flag'`` it is
        also renamed to ``<name>.corrupt`` so operators (and the crash-
        consistency tests) can see exactly what was rejected.
        """
        if on_corrupt not in ("flag", "ignore"):
            raise ValueError(
                f"on_corrupt must be 'flag' or 'ignore', got {on_corrupt!r}"
            )
        start_s, t0 = time.time(), time.perf_counter()
        restored = -1
        try:
            for step in reversed(self.steps()):
                path = self.step_path(step)
                try:
                    out = load_pytree(path, verify=verify)
                    restored = step
                    return out
                except CheckpointCorruptError:
                    if on_corrupt == "flag":
                        self._flag_corrupt(path)
            return None
        finally:
            self._record_op("restore", restored, start_s, t0)

    def _flag_corrupt(self, path: str) -> None:
        """Rename to a unique ``*.corrupt`` name; never let the rename
        itself abort the fallback walk (a step can be re-saved and go
        corrupt again after an earlier flag took the plain name)."""
        target = path + ".corrupt"
        n = 1
        while os.path.exists(target):
            target = f"{path}.corrupt.{n}"
            n += 1
        try:
            os.rename(path, target)
        except OSError:
            pass

    def corrupt_paths(self) -> list[str]:
        """Checkpoints flagged corrupt by :meth:`restore_latest`."""
        return sorted(
            os.path.join(self.root, n)
            for n in os.listdir(self.root)
            if ".corrupt" in n
        )
