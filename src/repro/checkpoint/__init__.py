"""Fault-tolerant checkpointing with elastic DP resume.

``store``   -- dependency-free sharded pytree store (atomic commit,
               content hashes, keep-last-K retention, corruption
               fallback).
``state``   -- versioned :class:`TrainState` bundling params, optimizer
               state, RNG key, step counter, data cursor, and telemetry
               calibrator state.
``elastic`` -- restore onto a different DP degree: host-side leaf
               resharding from manifest specs plus cursor rewriting;
               post-balancing is re-solved for the new shard count.
"""
from repro.checkpoint.elastic import (
    ElasticResumeError,
    elastic_cursor,
    meta_to_spec,
    reshard_pytree,
)
from repro.checkpoint.state import (
    DataCursor,
    TrainState,
    restore_train_state,
    save_train_state,
)
from repro.checkpoint.store import (
    CheckpointCorruptError,
    CheckpointManager,
    CheckpointOp,
    LeafInfo,
    load_manifest,
    load_pytree,
    save_pytree,
)

__all__ = [
    "CheckpointCorruptError",
    "CheckpointManager",
    "CheckpointOp",
    "DataCursor",
    "ElasticResumeError",
    "LeafInfo",
    "TrainState",
    "elastic_cursor",
    "load_manifest",
    "load_pytree",
    "meta_to_spec",
    "reshard_pytree",
    "restore_train_state",
    "save_pytree",
    "save_train_state",
]
