"""Versioned training state: what a resumable run must carry.

A :class:`TrainState` bundles

  * ``params`` and the AdamW optimizer state (validated against the
    :func:`repro.training.train_step.check_opt_state` contract on
    restore),
  * the jittable RNG key (if the run threads one),
  * the global ``step`` counter,
  * the data-pipeline cursor -- the synthetic stream's seed plus the
    next batch index, which is all
    :class:`~repro.data.pipeline.PrefetchingLoader` needs for
    bit-deterministic replay (every batch is derived from
    ``(seed, batch_index, attempt)``, never from consumption timing),
  * the telemetry calibrator state
    (:meth:`~repro.telemetry.adaptive.AdaptiveOrchestration.state_dict`)
    so adaptively fitted cost coefficients survive restarts instead of
    re-converging from the analytic prior.

The headline invariant (asserted in ``tests/test_checkpoint.py``): save
at step k, restore, and the continued loss trajectory is bitwise
identical to the uninterrupted run.  Restoring onto a *different* DP
degree goes through :mod:`repro.checkpoint.elastic`, which rewrites the
cursor for the new shard count; the orchestrator then re-solves
post-balancing, and the trajectory matches within numerical tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.checkpoint.store import CheckpointManager

__all__ = [
    "DataCursor",
    "TrainState",
    "restore_train_state",
    "save_train_state",
]

STATE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class DataCursor:
    """Resume point of the deterministic synthetic data stream.

    ``batch_index`` is the index of the NEXT batch to consume; the
    loader derives batch i's sampling RNG from ``(seed, i, attempt)``,
    so replay needs no fast-forwarding.
    """

    seed: int
    batch_index: int
    examples_per_instance: int
    d: int

    @property
    def total_examples(self) -> int:
        """Global examples per batch -- invariant under elastic resume."""
        return self.examples_per_instance * self.d

    def to_json(self) -> dict[str, int]:
        return {
            "seed": int(self.seed),
            "batch_index": int(self.batch_index),
            "examples_per_instance": int(self.examples_per_instance),
            "d": int(self.d),
        }

    @staticmethod
    def from_json(d: dict[str, int]) -> "DataCursor":
        return DataCursor(
            seed=int(d["seed"]),
            batch_index=int(d["batch_index"]),
            examples_per_instance=int(d["examples_per_instance"]),
            d=int(d["d"]),
        )


@dataclasses.dataclass
class TrainState:
    """Everything a run needs to continue exactly where it stopped."""

    params: Any
    opt_state: Any
    step: int
    cursor: DataCursor
    rng_key: np.ndarray | None = None
    calibrator: dict[str, Any] | None = None
    version: int = STATE_VERSION


def _state_tree(state: TrainState) -> dict[str, Any]:
    tree: dict[str, Any] = {
        "params": state.params,
        "opt_state": state.opt_state,
    }
    if state.rng_key is not None:
        tree["rng_key"] = np.asarray(state.rng_key)
    return tree


def save_train_state(
    manager: CheckpointManager,
    state: TrainState,
    *,
    specs: Any = None,
    meta: dict[str, Any] | None = None,
) -> str:
    """Commit ``state`` under ``step_<state.step>`` atomically.

    ``specs`` (optional) is a ``{"params": ..., "opt_state": ...}``
    pytree of PartitionSpecs recorded per leaf for elastic resharding.
    """
    extras = {
        "state_version": state.version,
        "step": int(state.step),
        "cursor": state.cursor.to_json(),
        "calibrator": state.calibrator,
        "has_rng_key": state.rng_key is not None,
    }
    return manager.save(
        state.step,
        _state_tree(state),
        specs=specs,
        extras=extras,
        meta=meta,
    )


def _state_from(tree: Any, manifest: dict[str, Any]) -> TrainState:
    extras = manifest["extras"]
    from repro.training.train_step import check_opt_state

    params = tree["params"]
    opt_state = tree["opt_state"]
    check_opt_state(params, opt_state)
    return TrainState(
        params=params,
        opt_state=opt_state,
        step=int(extras["step"]),
        cursor=DataCursor.from_json(extras["cursor"]),
        rng_key=tree.get("rng_key") if extras.get("has_rng_key") else None,
        calibrator=extras.get("calibrator"),
        version=int(extras.get("state_version", STATE_VERSION)),
    )


def restore_train_state(
    manager: CheckpointManager,
    *,
    step: int | None = None,
    verify: bool = True,
) -> tuple[TrainState, dict[str, Any]] | None:
    """Restore a :class:`TrainState` (newest complete one by default).

    Returns ``(state, manifest)``; ``None`` when the directory holds no
    restorable checkpoint.  Corrupt newest checkpoints are flagged and
    skipped (see ``CheckpointManager.restore_latest``).
    """
    if step is not None:
        tree, manifest = manager.restore(step, verify=verify)
        return _state_from(tree, manifest), manifest
    found = manager.restore_latest(verify=verify)
    if found is None:
        return None
    tree, manifest = found
    return _state_from(tree, manifest), manifest
