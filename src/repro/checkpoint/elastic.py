"""Elastic resume: restore a checkpoint onto a different DP world size.

This is where checkpointing meets the paper's core machinery.  Classic
data pipelines require the new DP degree to divide the old per-shard
layout; here nothing of the sort is needed, because the Batch
Post-Balancing Dispatcher re-solves example->shard assignments from
scratch every step.  Elastic resume therefore reduces to three
host-side moves:

  1. **Reshard the leaves.**  Checkpoint shards are stored as full
     host arrays; the manifest carries each leaf's original
     ``PartitionSpec``.  :func:`reshard_pytree` re-places every leaf
     onto the *new* mesh, dropping any spec axis the new mesh cannot
     honor (missing axis name or non-divisible dim) back to replicated.
  2. **Rewrite the data cursor.**  :func:`elastic_cursor` keeps the
     *global* batch (``d * examples_per_instance``) invariant and
     re-splits it across the new shard count, so the sampled example
     stream -- and hence the loss trajectory -- is unchanged up to
     floating-point reduction order.
  3. **Re-solve post-balancing.**  The caller rebuilds the orchestrator
     and loader at the new ``d`` (fresh ``Capacities``, fresh plan-ahead
     worker); any plan-ahead state from the old world size is invalid by
     construction and simply never restored -- plans are a pure function
     of (examples, d) and are recomputed on the first step.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.checkpoint.state import DataCursor
from repro.checkpoint.store import LeafInfo

__all__ = [
    "ElasticResumeError",
    "elastic_cursor",
    "meta_to_spec",
    "reshard_pytree",
]


class ElasticResumeError(ValueError):
    """The requested world-size change cannot preserve the data stream."""


def elastic_cursor(cursor: DataCursor, new_d: int) -> DataCursor:
    """Re-split the cursor's global batch across ``new_d`` DP shards.

    The global batch size must stay invariant (that is what makes the
    resumed loss trajectory comparable), so ``new_d`` must divide
    ``cursor.total_examples``.
    """
    if new_d < 1:
        raise ElasticResumeError(f"need new_d >= 1, got {new_d}")
    if cursor.d == new_d:
        return cursor
    total = cursor.total_examples
    if total % new_d:
        raise ElasticResumeError(
            f"global batch of {total} examples does not split across "
            f"{new_d} DP shards (was {cursor.d} x "
            f"{cursor.examples_per_instance}); pick a divisor of {total}"
        )
    return DataCursor(
        seed=cursor.seed,
        batch_index=cursor.batch_index,
        examples_per_instance=total // new_d,
        d=new_d,
    )


def meta_to_spec(meta: list[Any] | None, shape: tuple[int, ...], mesh: Any):
    """Manifest spec metadata -> a PartitionSpec valid on ``mesh``.

    Every recorded axis is kept only if the new mesh has it AND the
    corresponding array dim divides by its (new) size; otherwise that
    dim falls back to replicated.  This is what lets a checkpoint
    written under ``data=4`` land on a ``data=2`` (or ``data=8``) mesh
    without any divisibility precondition on the *old* layout.
    """
    from jax.sharding import PartitionSpec as P

    if meta is None or mesh is None:
        return P()
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts: list[Any] = []
    for dim, entry in enumerate(meta):
        if entry is None:
            parts.append(None)
            continue
        names = [entry] if isinstance(entry, str) else list(entry)
        if any(n not in axis_sizes for n in names):
            parts.append(None)
            continue
        size = int(np.prod([axis_sizes[n] for n in names]))
        if dim >= len(shape) or size < 1 or shape[dim] % size:
            parts.append(None)
            continue
        parts.append(entry if isinstance(entry, str) else tuple(names))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def reshard_pytree(tree: Any, manifest: dict[str, Any], mesh: Any) -> Any:
    """Host-side reshard of a restored tree onto ``mesh``.

    With ``mesh=None`` (single-host tests, CPU smoke runs) this is the
    identity.  Otherwise every leaf is ``device_put`` under the spec
    rebuilt by :func:`meta_to_spec` from its manifest row.
    """
    if mesh is None:
        return tree
    import jax
    from jax.sharding import NamedSharding

    infos = {row["path"]: LeafInfo.from_json(row) for row in manifest["leaves"]}

    def walk(node: Any, path: str) -> Any:
        if isinstance(node, dict):
            return {
                k: walk(v, f"{path}/{k}" if path else str(k))
                for k, v in node.items()
            }
        if isinstance(node, (list, tuple)):
            seq = [
                walk(v, f"{path}/{i}" if path else str(i))
                for i, v in enumerate(node)
            ]
            return seq if isinstance(node, list) else tuple(seq)
        info = infos.get(path)
        spec_meta = info.spec if info is not None else None
        spec = meta_to_spec(spec_meta, np.shape(node), mesh)
        return jax.device_put(node, NamedSharding(mesh, spec))

    return walk(tree, "")
