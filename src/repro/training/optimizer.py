"""AdamW + global-norm clipping + cosine schedule, pure JAX (no optax)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, step / jnp.maximum(warmup, 1))
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_init(params) -> dict[str, Any]:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p
    )
    return {"mu": zeros(params), "nu": zeros(params), "step": jnp.zeros((), jnp.int32)}


def adamw_update(
    params, grads, state, cfg: AdamWConfig, *, lr: jnp.ndarray | float | None = None
):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gnorm}
