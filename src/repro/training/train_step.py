"""Train / prefill step factories.

``make_train_step(cfg, mesh, ...)`` returns a jit-able function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` whose
forward pass embeds the orchestrator's communicator exchange (the
composed all-to-all) between encoder phases and the LLM backbone.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, with_attention_backend
from repro.core.communicator import apply_comm_plan
from repro.models.model import forward
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = [
    "METRIC_HELP",
    "check_opt_state",
    "make_exchange",
    "make_loss_fn",
    "make_train_step",
    "make_prefill_step",
]

# Catalog of every key the train-step metrics dict can carry.  The
# observability plane (repro.obs.ledger) republishes these host scalars
# as ``train_metric{name=...}`` gauges; this mapping is the single place
# their meaning is documented.
METRIC_HELP = {
    "loss": "mean next-token cross-entropy over supervised positions",
    "aux_loss": "MoE load-balance auxiliary loss (0 for dense families)",
    "tokens": "supervised positions in the step's global batch",
    "moe_dropped_frac": "routed tokens dropped at expert capacity "
                        "(0 on the drop-free grouped backend)",
    "moe_max_expert_load": "largest per-expert load fraction "
                           "(1/n_experts = perfectly balanced routing)",
    "grad_norm": "global gradient L2 norm",
}

# The optimizer-state contract ``make_train_step`` / ``adamw_update``
# expect -- and what a checkpoint must therefore carry.  Kept next to
# the step factory so the contract and its consumer move together.
OPT_STATE_KEYS = ("mu", "nu", "step")


def check_opt_state(params, opt_state) -> None:
    """Validate a (restored) optimizer state against the train-step
    contract: ``{"mu", "nu", "step"}`` with both moment trees congruent
    with ``params`` (same treedef, same leaf shapes) and a scalar step.

    Raises ``ValueError`` with the first violation -- this is what
    ``repro.checkpoint.state`` runs on every restore, so a checkpoint
    from an incompatible architecture fails loudly instead of crashing
    deep inside the jitted update."""
    if not isinstance(opt_state, dict) or set(opt_state) != set(OPT_STATE_KEYS):
        got = sorted(opt_state) if isinstance(opt_state, dict) else type(opt_state)
        raise ValueError(f"opt_state must have keys {OPT_STATE_KEYS}, got {got}")
    p_leaves, p_def = jax.tree_util.tree_flatten(params)
    for moment in ("mu", "nu"):
        m_leaves, m_def = jax.tree_util.tree_flatten(opt_state[moment])
        if m_def != p_def:
            raise ValueError(
                f"opt_state[{moment!r}] tree structure does not match params")
        for pl, ml in zip(p_leaves, m_leaves):
            if tuple(pl.shape) != tuple(ml.shape):
                raise ValueError(
                    f"opt_state[{moment!r}] leaf shape {tuple(ml.shape)} != "
                    f"params leaf shape {tuple(pl.shape)}")
    step = jnp.asarray(opt_state["step"])
    if step.ndim != 0:
        raise ValueError(f"opt_state['step'] must be a scalar, got {step.shape}")


def make_exchange(cfg: ModelConfig, mesh, dp_axes, *, mode: str = "a2a"):
    """Build the orchestrator's device-side exchange closure.

    Reads the per-encoder plan arrays out of the batch; moves encoder
    output tokens [S, cap_out_shard, D] -> destination shards.  With
    ``mesh=None`` (single-host tests) the exchange degrades to the
    'gather' mode: a plain global take with identical semantics."""

    def exchange_factory(batch):
        def exchange(name: str, enc_tok: jnp.ndarray) -> jnp.ndarray:
            S, T, D = enc_tok.shape
            plan = {
                "pre_gather_dense": batch[f"enc_{name}_plan_pre_gather_dense"],
                "post_gather_dense": batch[f"enc_{name}_plan_post_gather_dense"],
                "post_mask": batch[f"enc_{name}_plan_post_mask"],
                "global_gather": batch[f"enc_{name}_plan_global_gather"],
            }
            cap_out = plan["post_mask"].shape[-1]
            flat = enc_tok.reshape(S * T, D)
            if mesh is None:
                idx = plan["global_gather"].reshape(-1)
                mask = plan["post_mask"].reshape(-1)
                out = jnp.where(mask[:, None], jnp.take(flat, idx, axis=0), 0)
            else:
                out = apply_comm_plan(flat, plan, mesh, dp_axes, mode=mode)
            return out.reshape(S, cap_out, D)

        return exchange

    return exchange_factory


def make_loss_fn(cfg: ModelConfig, mesh=None, dp_axes=("data",), *,
                 comm_mode="a2a", attention_backend: str | None = None):
    """``attention_backend`` overrides ``cfg.attention_impl`` for every
    attention site inside the jitted loss/grad (e.g. "flash" to train on
    the Pallas path, "reference" for an oracle run)."""
    cfg = with_attention_backend(cfg, attention_backend)
    exchange_factory = make_exchange(cfg, mesh, dp_axes, mode=comm_mode)

    def loss_fn(params, batch):
        ex = exchange_factory(batch) if cfg.encoders else None
        loss_sum, n, aux = forward(cfg, params, batch, exchange=ex)
        n = jnp.maximum(n, 1)
        # moe family returns an aux metrics dict; only the load-balance
        # loss enters the objective, the rest surface as metrics.
        aux_loss = aux["lb_loss"] if isinstance(aux, dict) else aux
        loss = loss_sum / n + 0.01 * aux_loss
        metrics = {"loss": loss_sum / n, "aux_loss": aux_loss, "tokens": n}
        if isinstance(aux, dict):
            metrics["moe_dropped_frac"] = aux["dropped_frac"]
            metrics["moe_max_expert_load"] = aux["expert_load"].max()
        return loss, metrics

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig | None = None,
    mesh=None,
    dp_axes=("data",),
    *,
    comm_mode: str = "a2a",
    attention_backend: str | None = None,
):
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn = make_loss_fn(cfg, mesh, dp_axes, comm_mode=comm_mode,
                           attention_backend=attention_backend)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None, dp_axes=("data",), *,
                      comm_mode: str = "a2a",
                      attention_backend: str | None = None):
    """Forward-only (inference prefill): returns per-stream loss metrics.
    Serving prefill reuses the same packed-stream forward; logits for
    sampling come from the serve path."""
    loss_fn = make_loss_fn(cfg, mesh, dp_axes, comm_mode=comm_mode,
                           attention_backend=attention_backend)

    def prefill_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return prefill_step


def init_train_state(cfg: ModelConfig, key):
    from repro.models.model import init_params

    params = init_params(cfg, key)
    return params, adamw_init(params)
